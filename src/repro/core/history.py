"""Client behavioural history — paper §V-A/§V-B, array-backed.

For every client we track the three attributes the paper collects
(training time, missed rounds, cooldown) plus invocation bookkeeping
used by the selection algorithm (Alg. 2) and the bias metric.

The cooldown follows Eq. 1 of the paper:

    cooldown = 0            if the client completed training in time
             = 1            on a miss when cooldown == 0
             = cooldown * 2 on a miss otherwise

Storage is a flat struct-of-arrays keyed by a stable
`ClientInterner` index (core/interning.py): cooldown, invocation /
success / failure counts, last round, and the training-time aggregates
(count, max) live in NumPy arrays so the selection hot path — tier
predicates over a million registered clients — is a handful of
vectorized mask operations instead of a Python loop.  The two genuinely
ragged attributes (the training-time list and the missed-round list)
live in sparse per-index dicts: they only exist for clients that were
actually invoked, so their footprint scales with activity, not with the
registered population.

`ClientRecord` remains available in two forms: the standalone dataclass
(directly constructible, used by unit tests and the scalar feature
reference) and the `ClientRecordView` that `ClientHistoryDB.get`
returns — a thin view over the arrays exposing the exact same
attributes and mutators, so every pre-existing call site keeps working.

Persistence is batched: mutations only set a dirty flag, and the JSON
snapshot is written on an explicit `save()` (or every `flush_every`
mutations when configured) — never once per event.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field, asdict
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from .interning import ClientInterner, grow_to

# Smoothing factor of the *maintained* training-time EMA column.  The
# incremental update replays the exact scalar `features.ema` recurrence
# (acc = α·x + (1−α)·acc, seeded by the first observation), so reading
# the column is bit-identical to recomputing the EMA from the ragged
# list — but O(1) per propose instead of O(history).
DEFAULT_EMA_ALPHA = 0.5

# Dense missed-round mirror: rows wider than this fall back to the
# ragged path (a client missing 64+ rounds is pathological; don't let it
# inflate the (N × W) matrix for the whole fleet).
_MISS_DENSE_CAP = 64


@dataclass
class ClientRecord:
    """Behavioural record for one client (one row of the history DB).

    Standalone dataclass form — `ClientHistoryDB` rows are
    `ClientRecordView`s sharing this exact interface."""

    client_id: str
    training_times: List[float] = field(default_factory=list)
    missed_rounds: List[int] = field(default_factory=list)
    cooldown: int = 0
    invocations: int = 0
    successes: int = 0
    failures: int = 0
    last_round: int = -1

    # ---- tiering predicates (paper §V-A) -------------------------------
    @property
    def is_rookie(self) -> bool:
        """Never produced behavioural data: no recorded time and no miss."""
        return not self.training_times and not self.missed_rounds

    @property
    def is_straggler(self) -> bool:
        """Cooldown > 0 characterises tier-3 stragglers (paper §V-B)."""
        return self.cooldown > 0 and not self.is_rookie

    @property
    def is_participant(self) -> bool:
        return not self.is_rookie and not self.is_straggler

    # ---- Eq. 1 ----------------------------------------------------------
    def apply_success(self) -> None:
        """Controller observed an in-time completion → cooldown = 0."""
        self.cooldown = 0
        self.successes += 1

    def apply_miss(self, round_number: int) -> None:
        """Controller observed a miss/failure for `round_number` (Eq. 1)."""
        if round_number not in self.missed_rounds:
            self.missed_rounds.append(round_number)
        self.cooldown = 1 if self.cooldown == 0 else self.cooldown * 2
        self.failures += 1

    def correct_missed_round(self, round_number: int) -> None:
        """Client-side correction (Alg. 1 lines 24-26): a slow-but-alive
        client that finished late deletes the round from its missed list —
        distinguishing *slow* from *crashed* happens on the client side."""
        if round_number in self.missed_rounds:
            self.missed_rounds.remove(round_number)

    def record_training_time(self, seconds: float) -> None:
        self.training_times.append(float(seconds))

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ClientRecord":
        return cls(**d)


class ClientRecordView:
    """`ClientRecord`-shaped view over one row of the array store."""

    __slots__ = ("_db", "_idx")

    def __init__(self, db: "ClientHistoryDB", idx: int):
        self._db = db
        self._idx = idx

    # ---- attributes ----------------------------------------------------
    @property
    def client_id(self) -> str:
        return self._db._interner.id_of(self._idx)

    @property
    def training_times(self) -> List[float]:
        return self._db._times.get(self._idx, [])

    @property
    def missed_rounds(self) -> List[int]:
        return self._db._missed.get(self._idx, [])

    @property
    def cooldown(self) -> int:
        return int(self._db._cooldown[self._idx])

    @cooldown.setter
    def cooldown(self, value: int) -> None:
        self._db._cooldown[self._idx] = int(value)
        self._db._sync_tier(self._idx)
        self._db._touch()

    @property
    def invocations(self) -> int:
        return int(self._db._invocations[self._idx])

    @invocations.setter
    def invocations(self, value: int) -> None:
        self._db._invocations[self._idx] = int(value)
        self._db._touch()

    @property
    def successes(self) -> int:
        return int(self._db._successes[self._idx])

    @successes.setter
    def successes(self, value: int) -> None:
        self._db._successes[self._idx] = int(value)
        self._db._touch()

    @property
    def failures(self) -> int:
        return int(self._db._failures[self._idx])

    @failures.setter
    def failures(self, value: int) -> None:
        self._db._failures[self._idx] = int(value)
        self._db._touch()

    @property
    def last_round(self) -> int:
        return int(self._db._last_round[self._idx])

    @last_round.setter
    def last_round(self, value: int) -> None:
        self._db._last_round[self._idx] = int(value)
        self._db._touch()

    # ---- tiering predicates -------------------------------------------
    @property
    def is_rookie(self) -> bool:
        db, i = self._db, self._idx
        return db._n_times[i] == 0 and db._n_missed[i] == 0

    @property
    def is_straggler(self) -> bool:
        return self._db._cooldown[self._idx] > 0 and not self.is_rookie

    @property
    def is_participant(self) -> bool:
        return not self.is_rookie and not self.is_straggler

    # ---- mutators (same semantics as the dataclass) --------------------
    def apply_success(self) -> None:
        db, i = self._db, self._idx
        db._cooldown[i] = 0
        db._successes[i] += 1
        db._sync_tier(i)
        db._touch()

    def apply_miss(self, round_number: int) -> None:
        self._db._apply_miss(self._idx, round_number)

    def correct_missed_round(self, round_number: int) -> None:
        self._db._correct_missed_round(self._idx, round_number)

    def record_training_time(self, seconds: float) -> None:
        self._db._record_time(self._idx, seconds)

    def to_dict(self) -> dict:
        return {"client_id": self.client_id,
                "training_times": list(self.training_times),
                "missed_rounds": list(self.missed_rounds),
                "cooldown": self.cooldown, "invocations": self.invocations,
                "successes": self.successes, "failures": self.failures,
                "last_round": self.last_round}

    def __repr__(self) -> str:       # debugging convenience
        return f"ClientRecordView({self.to_dict()!r})"


class ClientHistoryDB:
    """The `client history` collection the paper adds to the FedLess DB
    (§IV-A), as a flat array store.  Thread-safe because the simulated
    FaaS platform completes invocations concurrently."""

    def __init__(self, path: Optional[str] = None, flush_every: int = 0):
        self._interner = ClientInterner()
        self._lock = threading.RLock()
        self._path = Path(path) if path else None
        # batched persistence: write on save()/flush cadence, not per event
        self.flush_every = int(flush_every)
        self._dirty = False
        self._mutations = 0
        self._alloc(0)
        self._times: Dict[int, List[float]] = {}
        self._missed: Dict[int, List[int]] = {}
        if self._path and self._path.exists():
            self.load(self._path)

    def _alloc(self, n: int) -> None:
        self._cooldown = np.zeros(n, np.int64)
        self._invocations = np.zeros(n, np.int64)
        self._successes = np.zeros(n, np.int64)
        self._failures = np.zeros(n, np.int64)
        self._last_round = np.full(n, -1, np.int64)
        self._n_times = np.zeros(n, np.int64)
        self._n_missed = np.zeros(n, np.int64)
        self._t_max = np.zeros(n, np.float64)
        # maintained aggregates for the propose hot path: training-time
        # EMA (incremental, DEFAULT_EMA_ALPHA) and an inf-padded dense
        # mirror of the missed-round lists (kept because the missed-EMA
        # depends on current_round and must be recomputed per propose —
        # off the matrix instead of 10⁶ ragged lists)
        self._t_ema = np.zeros(n, np.float64)
        # float32 shadow of _t_ema, downcast at write time — fleet-scale
        # feature builds gather it directly instead of converting an
        # 8 MB float64 gather per propose (same values: double→float
        # rounding is deterministic wherever it happens)
        self._t_ema32 = np.zeros(n, np.float32)
        self._missed_mat = np.full((n, 0), np.inf, np.float64)
        self._dense_miss = True
        # maintained tier codes (0 rookie / 1 participant / 2 straggler):
        # the §V-A predicates only change when a row mutates, so they are
        # synced per mutation and tier_masks is three int8 compares
        # instead of three int64 gathers plus the predicate algebra
        self._tier = np.zeros(n, np.int8)
        self._iota = np.arange(n)       # cached identity, for is_full_pool
        self._full_pool_idx = None      # last idx verified as the identity

    def _grow(self, n: int) -> None:
        if n <= self._cooldown.shape[0]:
            return
        self._cooldown = grow_to(self._cooldown, n)
        self._invocations = grow_to(self._invocations, n)
        self._successes = grow_to(self._successes, n)
        self._failures = grow_to(self._failures, n)
        self._last_round = grow_to(self._last_round, n, fill=-1)
        self._n_times = grow_to(self._n_times, n)
        self._n_missed = grow_to(self._n_missed, n)
        self._t_max = grow_to(self._t_max, n, fill=0.0)
        self._t_ema = grow_to(self._t_ema, n, fill=0.0)
        self._t_ema32 = grow_to(self._t_ema32, n, fill=0.0)
        self._missed_mat = grow_to(self._missed_mat, n, fill=np.inf)
        self._tier = grow_to(self._tier, n)     # fresh rows default rookie
        if self._cooldown.shape[0] > self._iota.shape[0]:
            self._iota = np.arange(self._cooldown.shape[0])

    # ---- bookkeeping ---------------------------------------------------
    def _touch(self) -> None:
        self._dirty = True
        self._mutations += 1
        if (self.flush_every and self._path is not None
                and self._mutations >= self.flush_every):
            self.save()

    def _intern(self, client_id: str) -> int:
        idx = self._interner.intern(client_id)
        self._grow(len(self._interner))
        return idx

    @property
    def size(self) -> int:
        return len(self._interner)

    @property
    def interner(self) -> ClientInterner:
        return self._interner

    # ---- CRUD ----------------------------------------------------------
    def get(self, client_id: str) -> ClientRecordView:
        with self._lock:
            return ClientRecordView(self, self._intern(client_id))

    def all(self) -> List[ClientRecordView]:
        with self._lock:
            return [ClientRecordView(self, i) for i in range(self.size)]

    def ensure(self, client_ids: Iterable[str]) -> None:
        with self._lock:
            self._interner.intern_many(
                client_ids if hasattr(client_ids, "__len__")
                else list(client_ids))
            self._grow(len(self._interner))

    # ---- row mutations (shared with ClientRecordView) ------------------
    def _sync_tier(self, idx: int) -> None:
        """Re-derive one row's maintained tier code after a mutation —
        every code path that writes _n_times/_n_missed/_cooldown must
        call this (the golden-trace parity tests gate it)."""
        if self._n_times[idx] == 0 and self._n_missed[idx] == 0:
            self._tier[idx] = 0
        elif self._cooldown[idx] > 0:
            self._tier[idx] = 2
        else:
            self._tier[idx] = 1

    def rebuild_tiers(self) -> None:
        """Vectorized tier recompute over every row — for bulk loads and
        direct array seeding (benchmarks), where per-row syncs would be
        O(n) Python calls."""
        rookie = (self._n_times == 0) & (self._n_missed == 0)
        tier = np.ones(self._n_times.shape[0], np.int8)
        tier[rookie] = 0
        tier[(self._cooldown > 0) & ~rookie] = 2
        self._tier = tier

    def _apply_miss(self, idx: int, round_number: int) -> None:
        missed = self._missed.setdefault(idx, [])
        if round_number not in missed:
            missed.append(round_number)
            self._n_missed[idx] = len(missed)
            self._sync_missed_row(idx)
        cd = self._cooldown[idx]
        self._cooldown[idx] = 1 if cd == 0 else cd * 2
        self._failures[idx] += 1
        self._sync_tier(idx)
        self._touch()

    def _correct_missed_round(self, idx: int, round_number: int) -> None:
        missed = self._missed.get(idx)
        if missed and round_number in missed:
            missed.remove(round_number)
            self._n_missed[idx] = len(missed)
            self._sync_missed_row(idx)
            self._sync_tier(idx)
            self._touch()

    def _sync_missed_row(self, idx: int) -> None:
        """Mirror one client's missed-round list into the dense matrix
        (rewriting the W≤cap row is cheaper than bookkeeping order)."""
        row = self._missed.get(idx, [])
        n = len(row)
        width = self._missed_mat.shape[1]
        if n > width:
            if n > _MISS_DENSE_CAP:
                self._dense_miss = False
            else:
                new_w = min(_MISS_DENSE_CAP, max(n, 2 * width, 4))
                pad = np.full((self._missed_mat.shape[0], new_w - width),
                              np.inf, np.float64)
                self._missed_mat = np.concatenate(
                    (self._missed_mat, pad), axis=1)
        if self._dense_miss:
            self._missed_mat[idx, :] = np.inf
            if n:
                self._missed_mat[idx, :n] = row

    def _record_time(self, idx: int, seconds: float) -> None:
        seconds = float(seconds)
        self._times.setdefault(idx, []).append(seconds)
        # incremental EMA — same op sequence as features.ema, so reading
        # _t_ema is bit-identical to recomputing over the ragged list
        if self._n_times[idx] == 0:
            self._t_ema[idx] = seconds
        else:
            self._t_ema[idx] = (DEFAULT_EMA_ALPHA * seconds
                                + (1.0 - DEFAULT_EMA_ALPHA)
                                * self._t_ema[idx])
        self._t_ema32[idx] = self._t_ema[idx]
        self._n_times[idx] += 1
        if seconds > self._t_max[idx]:
            self._t_max[idx] = seconds
        self._sync_tier(idx)
        self._touch()

    # ---- controller-side updates (Alg. 1, lines 5-13) ------------------
    def mark_success(self, client_id: str, round_number: int) -> None:
        with self._lock:
            idx = self._intern(client_id)
            self._cooldown[idx] = 0
            self._successes[idx] += 1
            self._last_round[idx] = round_number
            self._invocations[idx] += 1
            self._sync_tier(idx)
            self._touch()

    def mark_miss(self, client_id: str, round_number: int) -> None:
        with self._lock:
            idx = self._intern(client_id)
            self._apply_miss(idx, round_number)
            self._last_round[idx] = round_number
            self._invocations[idx] += 1

    # ---- client-side updates (Alg. 1, lines 16-27) ----------------------
    def client_report(self, client_id: str, round_number: int,
                      training_time: float) -> None:
        """A (possibly late) client pushes its measured training time and
        corrects its missed-rounds entry for the current round."""
        with self._lock:
            idx = self._intern(client_id)
            self._record_time(idx, training_time)
            self._correct_missed_round(idx, round_number)

    # ---- vectorized surface (core/selection.py hot path) ---------------
    def indices_for(self, client_ids: Sequence[str]) -> np.ndarray:
        """Array-index view of a pool sequence (memoized per object)."""
        with self._lock:
            idx = self._interner.indices_for(client_ids)
            self._grow(len(self._interner))
            return idx

    def is_full_pool(self, idx: np.ndarray) -> bool:
        """True when `idx` is the identity permutation 0..len-1 — i.e. the
        caller's pool is every registered client in registration order
        (the common fleet-scale propose).  Lets hot paths substitute
        O(1) slice views for O(n) fancy-index copies.  The interner
        memoizes `indices_for` per pool object, so across proposes the
        same pool yields the *same* ndarray — a verified array is
        remembered by identity and re-verifies O(1).  (Callers never
        mutate pool index arrays; `select_clients` builds new arrays
        when it filters.)"""
        n = idx.size
        if n != len(self._interner) or n == 0:
            return False
        if idx is self._full_pool_idx:
            return True
        full = (idx[0] == 0 and idx[n - 1] == n - 1
                and bool((idx == self._iota[:n]).all()))
        if full:
            self._full_pool_idx = idx
        return full

    def tier_masks(self, idx: np.ndarray, full_pool=None):
        """Vectorized §V-A tier predicates over index array `idx`:
        returns (rookie, participant, straggler) boolean masks.  Reads
        the maintained int8 tier codes — identical truth values to
        evaluating the predicates, at an eighth of the memory traffic.
        Callers that already ran `is_full_pool` pass it as `full_pool`
        to skip the O(n) re-check."""
        if full_pool is None:
            full_pool = self.is_full_pool(idx)
        if full_pool:                   # slice view, no gather copy
            tier = self._tier[:idx.size]
        else:
            tier = self._tier[idx]
        return tier == 0, tier == 1, tier == 2

    def t_max_masked(self, mask: np.ndarray) -> float:
        """Max t_max over the store rows selected by boolean `mask` —
        the full-pool hot path's alternative to gathering a 10^6-row
        subset just to reduce it.  Identical value to
        `t_max_of(idx).max()` over the same rows.  Multiply-by-mask
        stands in for a `where=` reduction (which numpy runs ~2x
        slower): t_max is ≥ 0, so zeroing the unselected rows never
        raises the max, and an all-False mask yields the same 0.0 the
        `initial=` would."""
        if mask.shape[0] == 0:
            return 0.0
        return float(np.max(self._t_max[:mask.shape[0]] * mask))

    def invocations_of(self, idx: np.ndarray) -> np.ndarray:
        return self._invocations[idx]

    def t_max_of(self, idx: np.ndarray) -> np.ndarray:
        return self._t_max[idx]

    def ids_of(self, idx: np.ndarray) -> List[str]:
        ids = self._interner.ids
        return [ids[i] for i in idx]

    def ragged_times(self, idx: np.ndarray) -> List[List[float]]:
        times = self._times
        return [times.get(int(i), []) for i in idx]

    def ragged_missed(self, idx: np.ndarray) -> List[List[int]]:
        missed = self._missed
        return [missed.get(int(i), []) for i in idx]

    def t_ema_of(self, idx: np.ndarray,
                 alpha: float = DEFAULT_EMA_ALPHA,
                 dtype=np.float64):
        """Maintained training-time EMA rows — O(|idx|) gather, bit-equal
        to recomputing over the ragged lists.  Returns None when `alpha`
        differs from the maintained smoothing factor (callers fall back
        to the ragged recompute).  `dtype=float32` reads the downcast
        shadow column — identical values to casting the float64 gather,
        at half the traffic."""
        if alpha != DEFAULT_EMA_ALPHA:
            return None
        if dtype == np.float32:
            return self._t_ema32[idx]
        return self._t_ema[idx]

    def missed_matrix(self, idx: np.ndarray):
        """(values, lengths): dense inf-padded missed-round rows for
        `idx`, trimmed to the widest selected row.  `values` is a
        fancy-index copy — callers may sort it in place.  Returns None
        when some client overflowed the dense cap (ragged fallback)."""
        if not self._dense_miss:
            return None
        lengths = self._n_missed[idx]
        w = int(lengths.max()) if lengths.size else 0
        if w == 0:                      # no selected row missed anything
            return np.empty((idx.size, 0), np.float64), lengths
        return self._missed_mat[np.ix_(idx, np.arange(w))], lengths

    # ---- tier partition (paper §V-A) --------------------------------------
    def partition(self, client_ids: Iterable[str]):
        """Partition into (rookies, participants, stragglers) — pool
        order preserved, one vectorized predicate pass."""
        with self._lock:
            if not hasattr(client_ids, "__len__"):
                client_ids = list(client_ids)
            idx = self.indices_for(client_ids)
            rookie, participant, straggler = self.tier_masks(idx)
            view = ClientRecordView
            rookies = [view(self, int(i)) for i in idx[rookie]]
            participants = [view(self, int(i)) for i in idx[participant]]
            stragglers = [view(self, int(i)) for i in idx[straggler]]
        return rookies, participants, stragglers

    # ---- persistence -------------------------------------------------------
    def to_payload(self) -> dict:
        """JSON-ready snapshot of every record (the checkpoint surface:
        fl/checkpointing.py embeds it in the round-tagged driver state)."""
        with self._lock:
            return {cid: ClientRecordView(self, i).to_dict()
                    for i, cid in enumerate(self._interner.ids)}

    def load_payload(self, payload: dict) -> None:
        """Restore from a `to_payload()` snapshot, replacing all records."""
        with self._lock:
            self._interner = ClientInterner(list(payload))
            n = len(self._interner)
            self._alloc(n)
            self._grow(n)
            self._times, self._missed = {}, {}
            for i, d in enumerate(payload.values()):
                self._cooldown[i] = int(d.get("cooldown", 0))
                self._invocations[i] = int(d.get("invocations", 0))
                self._successes[i] = int(d.get("successes", 0))
                self._failures[i] = int(d.get("failures", 0))
                self._last_round[i] = int(d.get("last_round", -1))
                times = [float(t) for t in d.get("training_times", [])]
                missed = [int(m) for m in d.get("missed_rounds", [])]
                if times:
                    self._times[i] = times
                    self._n_times[i] = len(times)
                    self._t_max[i] = max(times)
                    acc = times[0]
                    for v in times[1:]:     # replay features.ema exactly
                        acc = (DEFAULT_EMA_ALPHA * v
                               + (1.0 - DEFAULT_EMA_ALPHA) * acc)
                    self._t_ema[i] = acc
                if missed:
                    self._missed[i] = missed
                    self._n_missed[i] = len(missed)
                    self._sync_missed_row(i)
            self._t_ema32 = self._t_ema.astype(np.float32)
            self.rebuild_tiers()
            self._dirty = True

    def save(self, path: Optional[str] = None, force: bool = False) -> None:
        """Write the JSON snapshot.  With the instance's own path and no
        pending mutations this is a no-op (the dirty flag makes repeated
        checkpoint-time saves O(1) instead of O(N) JSON dumps)."""
        p = Path(path) if path else self._path
        if p is None:
            raise ValueError("no persistence path configured")
        if p == self._path and not self._dirty and not force:
            return
        payload = self.to_payload()
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(payload))
        if p == self._path:
            self._dirty = False
            self._mutations = 0

    def load(self, path) -> None:
        self.load_payload(json.loads(Path(path).read_text()))
        self._dirty = False
        self._mutations = 0
