"""Client behavioural history — paper §V-A/§V-B.

For every client we track the three attributes the paper collects
(training time, missed rounds, cooldown) plus invocation bookkeeping
used by the selection algorithm (Alg. 2) and the bias metric.

The cooldown follows Eq. 1 of the paper:

    cooldown = 0            if the client completed training in time
             = 1            on a miss when cooldown == 0
             = cooldown * 2 on a miss otherwise
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field, asdict
from pathlib import Path
from typing import Dict, Iterable, List, Optional


@dataclass
class ClientRecord:
    """Behavioural record for one client (one row of the history DB)."""

    client_id: str
    training_times: List[float] = field(default_factory=list)
    missed_rounds: List[int] = field(default_factory=list)
    cooldown: int = 0
    invocations: int = 0
    successes: int = 0
    failures: int = 0
    last_round: int = -1

    # ---- tiering predicates (paper §V-A) -------------------------------
    @property
    def is_rookie(self) -> bool:
        """Never produced behavioural data: no recorded time and no miss."""
        return not self.training_times and not self.missed_rounds

    @property
    def is_straggler(self) -> bool:
        """Cooldown > 0 characterises tier-3 stragglers (paper §V-B)."""
        return self.cooldown > 0 and not self.is_rookie

    @property
    def is_participant(self) -> bool:
        return not self.is_rookie and not self.is_straggler

    # ---- Eq. 1 ----------------------------------------------------------
    def apply_success(self) -> None:
        """Controller observed an in-time completion → cooldown = 0."""
        self.cooldown = 0
        self.successes += 1

    def apply_miss(self, round_number: int) -> None:
        """Controller observed a miss/failure for `round_number` (Eq. 1)."""
        if round_number not in self.missed_rounds:
            self.missed_rounds.append(round_number)
        self.cooldown = 1 if self.cooldown == 0 else self.cooldown * 2
        self.failures += 1

    def correct_missed_round(self, round_number: int) -> None:
        """Client-side correction (Alg. 1 lines 24-26): a slow-but-alive
        client that finished late deletes the round from its missed list —
        distinguishing *slow* from *crashed* happens on the client side."""
        if round_number in self.missed_rounds:
            self.missed_rounds.remove(round_number)

    def record_training_time(self, seconds: float) -> None:
        self.training_times.append(float(seconds))

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ClientRecord":
        return cls(**d)


class ClientHistoryDB:
    """The `client history` collection the paper adds to the FedLess DB
    (§IV-A).  In-memory with optional JSON persistence; thread-safe because
    the simulated FaaS platform completes invocations concurrently."""

    def __init__(self, path: Optional[str] = None):
        self._records: Dict[str, ClientRecord] = {}
        self._lock = threading.RLock()
        self._path = Path(path) if path else None
        if self._path and self._path.exists():
            self.load(self._path)

    # ---- CRUD ------------------------------------------------------------
    def get(self, client_id: str) -> ClientRecord:
        with self._lock:
            if client_id not in self._records:
                self._records[client_id] = ClientRecord(client_id=client_id)
            return self._records[client_id]

    def all(self) -> List[ClientRecord]:
        with self._lock:
            return list(self._records.values())

    def ensure(self, client_ids: Iterable[str]) -> None:
        for cid in client_ids:
            self.get(cid)

    # ---- controller-side updates (Alg. 1, lines 5-13) --------------------
    def mark_success(self, client_id: str, round_number: int) -> None:
        with self._lock:
            rec = self.get(client_id)
            rec.apply_success()
            rec.last_round = round_number
            rec.invocations += 1

    def mark_miss(self, client_id: str, round_number: int) -> None:
        with self._lock:
            rec = self.get(client_id)
            rec.apply_miss(round_number)
            rec.last_round = round_number
            rec.invocations += 1

    # ---- client-side updates (Alg. 1, lines 16-27) ------------------------
    def client_report(self, client_id: str, round_number: int,
                      training_time: float) -> None:
        """A (possibly late) client pushes its measured training time and
        corrects its missed-rounds entry for the current round."""
        with self._lock:
            rec = self.get(client_id)
            rec.record_training_time(training_time)
            rec.correct_missed_round(round_number)

    # ---- tier partition (paper §V-A) --------------------------------------
    def partition(self, client_ids: Iterable[str]):
        """Partition into (rookies, participants, stragglers)."""
        rookies, participants, stragglers = [], [], []
        with self._lock:
            for cid in client_ids:
                rec = self.get(cid)
                if rec.is_rookie:
                    rookies.append(rec)
                elif rec.is_straggler:
                    stragglers.append(rec)
                else:
                    participants.append(rec)
        return rookies, participants, stragglers

    # ---- persistence -------------------------------------------------------
    def to_payload(self) -> dict:
        """JSON-ready snapshot of every record (the checkpoint surface:
        fl/checkpointing.py embeds it in the round-tagged driver state)."""
        with self._lock:
            return {cid: rec.to_dict() for cid, rec in self._records.items()}

    def load_payload(self, payload: dict) -> None:
        """Restore from a `to_payload()` snapshot, replacing all records."""
        with self._lock:
            self._records = {
                cid: ClientRecord.from_dict(d) for cid, d in payload.items()
            }

    def save(self, path: Optional[str] = None) -> None:
        p = Path(path) if path else self._path
        if p is None:
            raise ValueError("no persistence path configured")
        payload = self.to_payload()
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(payload))

    def load(self, path) -> None:
        self.load_payload(json.loads(Path(path).read_text()))
