"""Unified delta-based merge pipeline with pluggable server optimizers.

Every strategy's model merge — FedAvg's cardinality-weighted average,
Eq. 3's staleness damping, FedAsync's mixing-rate merge, FedBuff's
buffered flush — is one algebraic shape:

    w' = ServerOpt(w, Δ),   Δ = mix · (Σ_k c_k · W_k − w)

i.e. a weighted sum of client updates forms a *pseudo-gradient* Δ against
the current global model, and a server-side optimizer decides how to fold
it in (Reddi et al., "Adaptive Federated Optimization", arXiv:2003.00295).
`mix` is 1 for the barrier strategies (the weighted sum replaces the
model outright when ServerOpt is the identity), FedAsync's staleness-
damped α_s, or FedBuff's server rate η.

`MergePipeline` owns that step for all strategies (core/strategies.py
constructs one per strategy from `StrategyConfig.server_opt*`):

* the **identity** server optimizer (``sgd`` with lr=1 and no momentum —
  the default) takes a fast path that reproduces the pre-pipeline
  behaviour *byte-identically*: the weighted sum (with the global model
  folded in as an anchor row when mix < 1) runs through the same
  `core.aggregation.aggregate` call, i.e. the Pallas `fed_agg` kernel;
* the adaptive optimizers — ``fedavgm`` (server momentum),
  ``fedadagrad``, ``fedadam``, ``fedyogi`` — keep fp32 moment pytrees
  (structure-sharing the model params, so checkpoints snapshot them with
  the existing array machinery) and dispatch the whole
  weighted-sum → Δ → moment-update → apply step as one fused Pallas
  kernel (`kernels.fed_agg_apply`); ``REPRO_AGG_KERNEL=0`` (or
  ``use_kernel=False``) reverts to a per-leaf `tree_map` twin built on
  the shared `optim.optimizers` pytree helpers.

Empty merges are uniform across strategies and training modes: no
updates → the global model is returned unchanged and ``last_update_norm``
reads 0.0 (the driver's aggregation trace record becomes the zero-delta
record).  `last_update_norm` always carries ‖Δ‖₂ of the latest merge on
the optimizer path — the fused kernel emits it as a per-tile Σ Δ² side
output, so the diagnostic costs no extra pass over the model.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from ..analysis import gates
from ..optim.optimizers import global_norm, zeros_like_f32
from .aggregation import (ClientUpdate, aggregate, aggregate_reference,
                          flat_update_matrix)

Pytree = Any

SERVER_OPTS = ("sgd", "fedavgm", "fedadagrad", "fedadam", "fedyogi")
# second-moment families (need the v buffer)
_ADAPTIVE = ("fedadagrad", "fedadam", "fedyogi")


@dataclass(frozen=True)
class ServerOptConfig:
    """Server optimizer family + hyperparameters (FedOpt conventions:
    no bias correction; `eps` is the adaptivity degree τ)."""
    name: str = "sgd"
    lr: float = 1.0
    momentum: float = 0.0         # heavy-ball β for sgd / fedavgm
    b1: float = 0.9               # first-moment decay (adaptive families)
    b2: float = 0.99              # second-moment decay (fedadam/fedyogi)
    eps: float = 1e-3

    def normalized(self) -> "ServerOptConfig":
        if self.name not in SERVER_OPTS:
            raise ValueError(f"unknown server optimizer {self.name!r}; "
                             f"available: {SERVER_OPTS}")
        # fedavgm *is* momentum — picking it with β=0 means the caller
        # wants the family default, not a silent plain-SGD
        if self.name == "fedavgm" and self.momentum == 0.0:
            return replace(self, momentum=0.9)
        return self

    @property
    def is_identity(self) -> bool:
        """Plain server-SGD with lr=1 and no momentum: w' = w + Δ, i.e.
        exactly the pre-pipeline replace-with-weighted-average."""
        return (self.name == "sgd" and self.lr == 1.0
                and self.momentum == 0.0)


class MergePipeline:
    """Delta-based merge: weighted sum → pseudo-gradient → server opt."""

    def __init__(self, config: Optional[ServerOptConfig] = None,
                 use_kernel: Optional[bool] = None,
                 mesh=None):
        self.config = (config or ServerOptConfig()).normalized()
        self.use_kernel = use_kernel    # None → REPRO_AGG_KERNEL env
        # jax.sharding.Mesh (>1 devices) → the flat weighted-sum and
        # fused-apply dispatches shard the P dim across it (shard_map);
        # None keeps the single-device path bit-for-bit
        self.mesh = mesh
        self.steps = 0                  # server-optimizer steps taken
        self.last_update_norm: Optional[float] = None   # ‖Δ‖₂
        self._m: Optional[Pytree] = None    # fp32 moment pytrees,
        self._v: Optional[Pytree] = None    # params tree structure
        self._unravel32 = None              # cached f32 unravel (kernel)

    @property
    def is_identity(self) -> bool:
        return self.config.is_identity

    def _kernel_enabled(self) -> bool:
        if self.use_kernel is not None:
            return self.use_kernel
        return gates.agg_kernel_enabled()

    # ------------------------------------------------------------------
    def merge(self, global_params: Optional[Pytree],
              updates: Sequence[ClientUpdate], coeffs,
              mix: float = 1.0) -> Optional[Pytree]:
        """Fold `updates` into `global_params`.

        coeffs are the caller's weighted-sum coefficients over `updates`
        (fedavg / staleness / buffer weights); `mix` scales the resulting
        pseudo-gradient (barrier strategies: 1.0, FedAsync: α_s,
        FedBuff: η).  With no updates the global model is returned
        unchanged — the unified empty-cohort / zero-update path.
        """
        if not updates:
            self.last_update_norm = 0.0
            return global_params
        coeffs = np.asarray(coeffs, dtype=np.float64)
        if self.is_identity:
            self.last_update_norm = None    # not computed on the fast path
            return self._merge_identity(global_params, list(updates), coeffs,
                                        mix)
        if global_params is None:
            raise ValueError(
                f"server optimizer {self.config.name!r} is delta-based and "
                f"needs the current global params")
        new_params = self._merge_opt(global_params, list(updates), coeffs,
                                     float(mix))
        self.steps += 1
        return new_params

    # ---- identity fast path (byte-identical legacy behaviour) --------
    def _merge_identity(self, global_params, updates: List[ClientUpdate],
                        coeffs: np.ndarray, mix: float) -> Pytree:
        if mix >= 1.0:
            # w' = w + (Σ c·W − w) = Σ c·W — the exact pre-pipeline call
            return aggregate(updates, coeffs, use_kernel=self.use_kernel,
                             mesh=self.mesh)
        if global_params is None:
            raise ValueError("mix < 1 folds the global model in as an "
                             "anchor; global params are required")
        anchor = ClientUpdate("__global__", global_params, num_samples=0,
                              round_number=updates[0].round_number)
        folded = np.concatenate(([1.0 - mix], mix * coeffs))
        return aggregate([anchor] + updates, folded,
                         use_kernel=self.use_kernel, mesh=self.mesh)

    # ---- optimizer path ----------------------------------------------
    def _merge_opt(self, global_params, updates: List[ClientUpdate],
                   coeffs: np.ndarray, mix: float) -> Pytree:
        if self._kernel_enabled():
            try:
                return self._apply_kernel(global_params, updates, coeffs,
                                          mix)
            except (TypeError, ValueError) as e:
                # exotic pytrees that ravel_pytree/stack can't flatten
                import warnings
                warnings.warn(f"fed_agg_apply kernel path fell back to "
                              f"the tree_map reference path: {e}")
        return self._apply_tree(global_params, updates, coeffs, mix)

    def _kernel_scalars(self):
        c = self.config
        b1 = c.momentum if c.name in ("sgd", "fedavgm") else c.b1
        return c.lr, b1, c.b2, c.eps

    def _apply_kernel(self, global_params, updates, coeffs, mix):
        # deferred import: kernels pull in pallas
        from ..kernels import fed_agg_apply, fed_agg_apply_sharded

        flat_g, unravel = ravel_pytree(global_params)
        # zero-copy on the device pipeline: batch-backed updates gather
        # rows straight out of the executor's (K, P) matrix
        mat, _ = flat_update_matrix(updates)
        if mat.shape[1] != flat_g.shape[0]:
            # a genuine layout error, not an exotic-pytree condition —
            # RuntimeError so the fallback handler doesn't mislabel it
            raise RuntimeError(
                f"update/global size mismatch: updates ravel to "
                f"{mat.shape[1]} parameters, global model to "
                f"{flat_g.shape[0]}")
        # distinct fresh zero buffers — m and v are donated separately,
        # so they must never share storage
        flat_m = (ravel_pytree(self._m)[0] if self._m is not None
                  else jnp.zeros_like(flat_g, dtype=jnp.float32))
        flat_v = (ravel_pytree(self._v)[0] if self._v is not None
                  else jnp.zeros_like(flat_g, dtype=jnp.float32))
        lr, b1, b2, eps = self._kernel_scalars()
        if self.mesh is not None and int(self.mesh.size) > 1:
            out, m_new, v_new, norm = fed_agg_apply_sharded(
                mat, jnp.asarray(coeffs, dtype=jnp.float32), flat_g,
                flat_m, flat_v, lr, mix, b1, b2, eps,
                opt=self.config.name, mesh=self.mesh)
        else:
            # donate the merge matrix and the flat moment buffers (all
            # rebuilt fresh next round) — NEVER flat_g: the caller's
            # strategy retains global_params across the merge
            out, m_new, v_new, norm = fed_agg_apply(
                mat, jnp.asarray(coeffs, dtype=jnp.float32), flat_g,
                flat_m, flat_v, lr, mix, b1, b2, eps,
                opt=self.config.name, donate=True)
        # moments unravel through an f32 view of the params structure:
        # the params-derived `unravel` would round-trip every leaf via
        # the param dtype, silently quantizing fp32 moment state for
        # low-precision models (the view is cached — the tree structure
        # is fixed for the pipeline's lifetime)
        if self._unravel32 is None:
            _, self._unravel32 = ravel_pytree(zeros_like_f32(global_params))
        self._m = self._unravel32(m_new)
        if self.config.name in _ADAPTIVE:
            self._v = self._unravel32(v_new)
        self.last_update_norm = float(norm)
        # cast to the *promoted* flat dtype; unravel itself restores each
        # leaf's own dtype (mixed-precision trees keep full precision)
        return unravel(out.astype(flat_g.dtype))

    def _apply_tree(self, global_params, updates, coeffs, mix):
        """Per-leaf `tree_map` twin of the fused kernel (validation path,
        and the fallback for pytrees the flattened layout can't take)."""
        c = self.config
        tm = jax.tree_util.tree_map
        avg = aggregate_reference(updates, coeffs)
        delta = tm(lambda a, g: jnp.float32(mix)
                   * (a.astype(jnp.float32) - g.astype(jnp.float32)),
                   avg, global_params)
        if self._m is None:
            self._m = zeros_like_f32(global_params)
        if c.name in ("sgd", "fedavgm"):
            self._m = tm(lambda m, d: c.momentum * m + d, self._m, delta)
            step = self._m
        else:
            if self._v is None:
                self._v = zeros_like_f32(global_params)
            self._m = tm(lambda m, d: c.b1 * m + (1.0 - c.b1) * d,
                         self._m, delta)
            if c.name == "fedadagrad":
                self._v = tm(lambda v, d: v + d * d, self._v, delta)
            elif c.name == "fedadam":
                self._v = tm(lambda v, d: c.b2 * v + (1.0 - c.b2) * d * d,
                             self._v, delta)
            else:                                           # fedyogi
                self._v = tm(
                    lambda v, d: v - (1.0 - c.b2) * d * d
                    * jnp.sign(v - d * d), self._v, delta)
            step = tm(lambda m, v: m / (jnp.sqrt(v) + c.eps),
                      self._m, self._v)
        self.last_update_norm = float(global_norm(delta))
        return tm(lambda g, s: (g.astype(jnp.float32)
                                + c.lr * s).astype(g.dtype),
                  global_params, step)

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self, arrays: Optional[dict] = None) -> dict:
        """Moment pytrees go into `arrays` (they share the global model's
        tree structure, so the checkpointer's array store handles them)."""
        arrays = {} if arrays is None else arrays
        state = {"name": self.config.name, "steps": self.steps}
        if self._m is not None:
            arrays["server_opt/m"] = self._m
            state["has_m"] = True
        if self._v is not None:
            arrays["server_opt/v"] = self._v
            state["has_v"] = True
        return state

    def load_state_dict(self, state: dict,
                        arrays: Optional[dict] = None) -> None:
        """Missing state (moment-free checkpoints from before the merge
        pipeline) restores as a fresh optimizer — the documented
        migration: moments re-accumulate from the resume point."""
        arrays = {} if arrays is None else arrays
        if not state:
            return
        name = state.get("name")
        if name is not None and name != self.config.name:
            raise ValueError(f"checkpoint was written with server "
                             f"optimizer {name!r}, pipeline runs "
                             f"{self.config.name!r}")
        self.steps = int(state.get("steps", 0))
        as_f32 = lambda t: jax.tree_util.tree_map(
            lambda l: jnp.asarray(l, dtype=jnp.float32), t)
        self._m = (as_f32(arrays["server_opt/m"])
                   if state.get("has_m") else None)
        self._v = (as_f32(arrays["server_opt/v"])
                   if state.get("has_v") else None)
