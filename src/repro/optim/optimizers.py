"""Optimizers from scratch (no optax in this environment).

Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``.  All states are pytrees so they pjit/shard like params.

FedProx support: `proximal_grad` adds mu * (w - w_global) to the gradient,
which is the gradient of the paper's proximal term mu/2 ||w - w_global||^2.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree]]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


def zeros_like_f32(params: Pytree) -> Pytree:
    """fp32 moment buffers shaped like `params` (mixed-precision training
    and the server-side merge pipeline keep fp32 optimizer state even
    when the params themselves are lower precision)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)


_zeros_like_f32 = zeros_like_f32


# --------------------------------------------------------------------------
def sgd(learning_rate: float, momentum: float = 0.0) -> Optimizer:
    """SGD, optionally with heavy-ball momentum. State: (count, velocity?)."""

    def init(params):
        if momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {"count": jnp.zeros((), jnp.int32),
                "velocity": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            updates = jax.tree_util.tree_map(
                lambda g: -learning_rate * g.astype(jnp.float32), grads)
            return updates, {"count": state["count"] + 1}
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g.astype(jnp.float32),
            state["velocity"], grads)
        updates = jax.tree_util.tree_map(lambda v: -learning_rate * v, vel)
        return updates, {"count": state["count"] + 1, "velocity": vel}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
def adam(learning_rate: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when weight_decay > 0).

    m/v accumulators are fp32 regardless of param dtype (mixed-precision
    training keeps bf16 params with fp32 optimizer state).
    """

    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": _zeros_like_f32(params),
                "v": _zeros_like_f32(params)}

    def update(grads, state, params):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** cf
        bc2 = 1 - b2 ** cf

        def step(m_, v_, p):
            upd = -learning_rate * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd - learning_rate * weight_decay * p.astype(jnp.float32)
            return upd

        updates = jax.tree_util.tree_map(step, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(learning_rate: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(learning_rate, weight_decay=weight_decay, **kw)


OPTIMIZERS = {"sgd": sgd, "adam": adam, "adamw": adamw}


def make_optimizer(name: str, learning_rate: float, **kw) -> Optimizer:
    try:
        return OPTIMIZERS[name](learning_rate, **kw)
    except KeyError:
        raise ValueError(f"unknown optimizer {name!r}") from None


# --------------------------------------------------------------------------
def proximal_grad(grads: Pytree, params: Pytree, global_params: Pytree,
                  mu: float) -> Pytree:
    """FedProx: grad += mu * (w - w_global)  (gradient of mu/2||w - w_g||²)."""
    if mu == 0.0:
        return grads
    return jax.tree_util.tree_map(
        lambda g, p, gp: g + mu * (p - gp).astype(g.dtype),
        grads, params, global_params)


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads)
