from .optimizers import (OPTIMIZERS, Optimizer, adam, adamw, apply_updates,
                         clip_by_global_norm, global_norm, make_optimizer,
                         proximal_grad, sgd, zeros_like_f32)

__all__ = ["OPTIMIZERS", "Optimizer", "adam", "adamw", "apply_updates",
           "clip_by_global_norm", "global_norm", "make_optimizer",
           "proximal_grad", "sgd", "zeros_like_f32"]
