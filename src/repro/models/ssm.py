"""Mamba2 block — SSD (state-space duality) with chunked scan.

Faithful to Dao & Gu 2024 (arXiv:2405.21060): scalar-per-head A, depthwise
causal conv on (x, B, C), softplus dt, gated RMSNorm, chunked SSD that
computes intra-chunk terms as masked matmuls (MXU-friendly on TPU) and
carries inter-chunk states with lax.scan.  Decode is the O(1) recurrence
  h ← exp(A·dt)·h + dt·B⊗x ;  y = C·h + D·x.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import he_init, rms_norm

Pytree = Any


def _dims(cfg: ArchConfig):
    d_inner = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N          # conv over (x, B, C); one group
    d_in_proj = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return d_inner, H, P, N, conv_dim, d_in_proj


def mamba_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> Pytree:
    D = cfg.d_model
    d_inner, H, P, N, conv_dim, d_in_proj = _dims(cfg)
    ks = jax.random.split(rng, 5)
    dt = jnp.exp(jax.random.uniform(ks[3], (H,)) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    return {
        "in_proj": he_init(ks[0], (D, d_in_proj), D, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, cfg.ssm_conv))
                   * (1.0 / jnp.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0,
                                            maxval=16.0)).astype(jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": he_init(ks[4], (d_inner, D), d_inner, dtype),
    }


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    d_inner, H, P, N, _, _ = _dims(cfg)
    z, x, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, x, B, C, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal 1-d conv. xbc: (B, S, C); w: (C, K)."""
    K = w.shape[1]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    y = _conv_unrolled(pad, w, K)
    return jax.nn.silu(y + b.astype(y.dtype))


def _conv_unrolled(padded: jnp.ndarray, w: jnp.ndarray, K: int):
    """Small-K depthwise conv as a sum of shifted slices (K ≤ 4)."""
    S = padded.shape[1] - (K - 1)
    acc = None
    for i in range(K):
        term = padded[:, i:i + S, :] * w[:, i].astype(padded.dtype)
        acc = term if acc is None else acc + term
    return acc


def _segsum(t: jnp.ndarray) -> jnp.ndarray:
    """(..., q) → (..., q, q) with out[i, j] = sum_{k=j+1..i} t[k] (i ≥ j)."""
    cs = jnp.cumsum(t, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    q = t.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, a_dt: jnp.ndarray, B: jnp.ndarray,
                C: jnp.ndarray, chunk: int = 128,
                init_state: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (pure jnp oracle; Pallas kernel mirrors this).

    x (b,l,h,p) — already scaled by dt;  a_dt (b,l,h) = A·dt;
    B, C (b,l,h,n).  Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    c = l // chunk
    xc = x.reshape(b, c, chunk, h, p)
    Bc = B.reshape(b, c, chunk, h, n)
    Cc = C.reshape(b, c, chunk, h, n)
    a = a_dt.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)     # (b,h,c,q)
    a_cum = jnp.cumsum(a, axis=-1)

    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(a))                                     # (b,h,c,q,q)
    y_diag = jnp.einsum("bcqhn,bcshn,bhcqs,bcshp->bcqhp",
                        Cc, Bc, L.astype(x.dtype), xc)

    # per-chunk output states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (b,h,c,q)
    states = jnp.einsum("bcqhn,bhcq,bcqhp->bchpn",
                        Bc, decay_states.astype(x.dtype), xc)   # (b,c,h,p,n)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                       # (b,h,c)
    s0 = (init_state if init_state is not None
          else jnp.zeros((b, h, p, n), x.dtype))

    def step(carry, inp):
        st, dec = inp                                           # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None].astype(x.dtype) + st
        return new, carry                                       # emit prev

    final_state, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0),
                   jnp.moveaxis(chunk_decay, 2, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # (b,c,h,p,n)

    # off-diagonal: contribution of the state entering each chunk
    state_decay = jnp.exp(a_cum)                                # (b,h,c,q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp",
                       Cc, prev_states, state_decay.astype(x.dtype))
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def mamba_block(p: Pytree, x: jnp.ndarray, cfg: ArchConfig,
                chunk: int = 128, return_cache: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, D) → (B, S, D)."""
    Bsz, S, D = x.shape
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, proj)

    xbc_raw = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                        # (B,S,H)
    A = -jnp.exp(p["A_log"])                                    # (H,)
    xh = xs.reshape(Bsz, S, H, P)
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (Bsz, S, H, N)).astype(x.dtype)
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (Bsz, S, H, N)).astype(x.dtype)

    ck = min(chunk, S)
    while S % ck:
        ck -= 1
    y, final_state = ssd_chunked(xh * dt[..., None].astype(x.dtype),
                                 (A[None, None, :] * dt), Bh, Ch, chunk=ck)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    if return_cache:
        K = cfg.ssm_conv
        tail = xbc_raw[:, -(K - 1):, :]
        if S < K - 1:
            tail = jnp.pad(xbc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        cache = {"conv": tail.astype(jnp.float32),
                 "ssm": final_state.astype(jnp.float32)}
        return out, cache
    return out


# ----------------------------------------------------------------- decode
def init_mamba_cache(cfg: ArchConfig, batch: int,
                     dtype=jnp.float32) -> Pytree:
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
            "ssm": jnp.zeros((batch, H, P, N), dtype)}


def mamba_decode_step(p: Pytree, x: jnp.ndarray, cache: Pytree,
                      cfg: ArchConfig) -> Tuple[jnp.ndarray, Pytree]:
    """One-token decode. x: (B, 1, D)."""
    Bsz = x.shape[0]
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))[:, 0]
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, proj)

    xbc_new = jnp.concatenate([xs, Bm, Cm], axis=-1)             # (B, conv_dim)
    window = jnp.concatenate(
        [cache["conv"].astype(x.dtype), xbc_new[:, None, :]], axis=1)
    w = p["conv_w"].astype(x.dtype)                              # (C, K)
    y_conv = jnp.einsum("bkc,ck->bc", window, w) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(y_conv)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(A[None, :] * dt)                                 # (B,H)
    xh = xs.reshape(Bsz, H, P)
    h_prev = cache["ssm"].astype(jnp.float32)
    dBx = (dt[..., None, None] * Bm.astype(jnp.float32)[:, None, None, :]
           * xh.astype(jnp.float32)[..., None])                  # (B,H,P,N)
    h = a[..., None, None] * h_prev + dBx
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y.astype(x.dtype) + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bsz, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(x.dtype))
    new_cache = {"conv": window[:, 1:, :].astype(cache["conv"].dtype),
                 "ssm": h.astype(cache["ssm"].dtype)}
    return out[:, None, :], new_cache
