"""GQA attention: full/sliding-window causal (train & prefill), cross
attention (VLM), and single-token decode against a KV cache.

Layouts (head dims kept explicit so sharding rules can target them):
  wq: (D, H, hd)   wk/wv: (D, K, hd)   wo: (H, hd, D)
  KV cache: (B, K, S_cache, hd); window layers use a ring buffer.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import apply_rope, he_init, softcap

Pytree = Any

NEG_INF = -2.3819763e38  # large negative for masking in fp32


def attn_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> Pytree:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    return {"wq": he_init(ks[0], (D, H, hd), D, dtype),
            "wk": he_init(ks[1], (D, K, hd), D, dtype),
            "wv": he_init(ks[2], (D, K, hd), D, dtype),
            "wo": he_init(ks[3], (H, hd, D), H * hd, dtype)}


def _qkv(p: Pytree, x: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """q: (B,Sq,H,hd), k: (B,Sk,K,hd) → scores (B,K,G,Sq,Sk), G=H/K."""
    B, Sq, H, hd = q.shape
    qg = q.reshape(B, Sq, n_kv, H // n_kv, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs: (B,K,G,Sq,Sk), v: (B,Sk,K,hd) → (B,Sq,H,hd)."""
    B, K, G, Sq, _ = probs.shape
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return o.reshape(B, Sq, K * G, v.shape[-1])


def _causal_window_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                        window: Optional[int]) -> jnp.ndarray:
    """(Sq, Sk) boolean mask: True = attend."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _softmax(scores: jnp.ndarray, mask: jnp.ndarray,
             cap: float, fp32: bool = True) -> jnp.ndarray:
    if fp32:
        s = softcap(scores.astype(jnp.float32), cap)
        s = jnp.where(mask, s, NEG_INF)
        return jax.nn.softmax(s, axis=-1)
    # bf16 softmax path (§Perf): halves the (B,K,G,Sq,Sk) tensor traffic;
    # max-subtraction keeps it stable, mask value fits bf16 range
    s = softcap(scores, cap)
    s = jnp.where(mask, s, jnp.asarray(-3e38, s.dtype))
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


# --------------------------------------------------------------- full seq
def self_attention(p: Pytree, x: jnp.ndarray, positions: jnp.ndarray,
                   cfg: ArchConfig, window: Optional[int] = None,
                   q_chunk: int = 1024, return_kv: bool = False):
    """Causal (optionally windowed) self-attention over a full sequence.

    For long sequences the query dimension is processed in chunks via
    lax.scan — the pure-jnp analogue of the Pallas flash kernel: live
    buffers stay O(q_chunk · S) instead of O(S²).
    """
    B, S, D = x.shape
    q, k, v = _qkv(p, x)
    q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)

    if cfg.use_pallas_attention:
        # Pallas flash kernel path (TPU target): (B,S,H,hd) → (B,H,S,hd)
        from ..kernels import flash_attention as _flash
        o = _flash(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                   jnp.swapaxes(v, 1, 2), causal=True, window=window,
                   softcap=cfg.attn_logit_softcap)
        o = jnp.swapaxes(o, 1, 2).astype(x.dtype)
    elif S <= q_chunk:
        mask = _causal_window_mask(positions[0], positions[0], window)
        probs = _softmax(_gqa_scores(q, k, cfg.n_kv_heads), mask,
                         cfg.attn_logit_softcap,
                         cfg.attn_fp32_softmax).astype(x.dtype)
        o = _gqa_out(probs, v)
    else:
        n_chunks = S // q_chunk
        assert S % q_chunk == 0, f"seq {S} not divisible by q_chunk {q_chunk}"
        qs = q.reshape(B, n_chunks, q_chunk, *q.shape[2:])
        pos = positions[0].reshape(n_chunks, q_chunk)

        def body(_, inp):
            q_c, pos_c = inp
            mask = _causal_window_mask(pos_c, positions[0], window)
            pr = _softmax(_gqa_scores(q_c, k, cfg.n_kv_heads), mask,
                          cfg.attn_logit_softcap,
                          cfg.attn_fp32_softmax).astype(x.dtype)
            return None, _gqa_out(pr, v)

        _, o = jax.lax.scan(body, None,
                            (jnp.moveaxis(qs, 1, 0), pos))
        o = jnp.moveaxis(o, 0, 1).reshape(B, S, q.shape[2], q.shape[3])

    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


def kv_to_cache(k: jnp.ndarray, v: jnp.ndarray, window: Optional[int],
                dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Convert prefill (B, S, K, hd) roped keys/values into the decode
    cache layout (B, K, S_cache, hd).  Window layers keep the last
    `window` entries arranged by ring-buffer slot (t % window) so decode
    can continue writing at position S."""
    B, S, K, hd = k.shape
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if window and S > window:
        slots = jnp.arange(window)
        # slot i holds the largest t < S with t % window == i
        t = (S - 1) - ((S - 1 - slots) % window)
        kt = kt[:, :, t, :]
        vt = vt[:, :, t, :]
    elif window and S <= window:
        pad = window - S
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return kt.astype(dtype), vt.astype(dtype)


# --------------------------------------------------------------- cross
def cross_attention(p: Pytree, x: jnp.ndarray,
                    kv_feats: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Text queries attend over (unmasked) vision features (B, P, D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bpd,dhk->bphk", kv_feats, p["wk"].astype(x.dtype))
    v = jnp.einsum("bpd,dhk->bphk", kv_feats, p["wv"].astype(x.dtype))
    scores = _gqa_scores(q, k, cfg.n_kv_heads)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = _gqa_out(probs, v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


# --------------------------------------------------------------- decode
def init_kv_cache(cfg: ArchConfig, batch: int, length: int,
                  dtype=jnp.bfloat16) -> Pytree:
    K, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((batch, K, length, hd), dtype),
            "v": jnp.zeros((batch, K, length, hd), dtype)}


def decode_self_attention(p: Pytree, x: jnp.ndarray, cache: Pytree,
                          pos: jnp.ndarray, cfg: ArchConfig,
                          window: Optional[int] = None
                          ) -> Tuple[jnp.ndarray, Pytree]:
    """One-token decode. x: (B, 1, D); pos: (B,) current positions.

    Full-attention layers use a cache of the full context; window layers a
    ring buffer of size `window` (keys are roped at absolute positions
    before caching, so the ring wrap is transparent).
    """
    S_cache = cache["k"].shape[2]
    q, k_new, v_new = _qkv(p, x)
    q = apply_rope(q, pos[:, None], cfg.rope_fraction, cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_fraction, cfg.rope_theta)

    slot = (pos % S_cache) if window else jnp.minimum(pos, S_cache - 1)
    # scatter the new kv at each batch row's slot
    k_cache = _scatter_time(cache["k"], k_new.astype(cache["k"].dtype), slot)
    v_cache = _scatter_time(cache["v"], v_new.astype(cache["v"].dtype), slot)

    scores = _gqa_scores(q, jnp.swapaxes(k_cache, 1, 2).astype(x.dtype),
                         cfg.n_kv_heads)                     # (B,K,G,1,S)
    idx = jnp.arange(S_cache)
    if window:
        # ring buffer: a slot is valid if written within the last `window`
        # steps, i.e. slot index corresponds to some t in (pos-window, pos]
        valid = _ring_valid(idx, pos, S_cache)               # (B, S)
    else:
        valid = idx[None, :] <= pos[:, None]
    mask = valid[:, None, None, None, :]
    s = softcap(scores.astype(jnp.float32), cfg.attn_logit_softcap)
    s = jnp.where(mask, s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = _gqa_out(probs, jnp.swapaxes(v_cache, 1, 2).astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache}


def _scatter_time(cache: jnp.ndarray, new: jnp.ndarray,
                  slot: jnp.ndarray) -> jnp.ndarray:
    """cache (B,K,S,hd) ← new (B,1,K,hd) at per-row time index slot (B,)."""
    S = cache.shape[2]
    onehot = jax.nn.one_hot(slot, S, dtype=cache.dtype)      # (B, S)
    newt = jnp.swapaxes(new, 1, 2)                            # (B,K,1,hd)
    return cache * (1 - onehot[:, None, :, None]) + \
        newt * onehot[:, None, :, None]


def _ring_valid(idx: jnp.ndarray, pos: jnp.ndarray, S: int) -> jnp.ndarray:
    """Valid slots of a ring buffer of size S after writing position pos."""
    # slot i currently holds time t(i) = the largest t ≤ pos with t % S == i
    p = pos[:, None]
    t = p - ((p - idx[None, :]) % S)
    return (t >= 0) & (t >= p - S + 1)


def init_cross_cache(p: Pytree, kv_feats: jnp.ndarray,
                     dtype=jnp.bfloat16) -> Pytree:
    """Precompute cross-attention K/V from vision features once."""
    k = jnp.einsum("bpd,dhk->bphk", kv_feats, p["wk"].astype(kv_feats.dtype))
    v = jnp.einsum("bpd,dhk->bphk", kv_feats, p["wv"].astype(kv_feats.dtype))
    return {"ck": k.astype(dtype), "cv": v.astype(dtype)}


def decode_cross_attention(p: Pytree, x: jnp.ndarray, cross_cache: Pytree,
                           cfg: ArchConfig) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = cross_cache["ck"].astype(x.dtype)
    v = cross_cache["cv"].astype(x.dtype)
    scores = _gqa_scores(q, k, cfg.n_kv_heads)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = _gqa_out(probs, v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
