"""Architecture config — one dataclass covers all six assigned arch types.

The per-layer structure is a repeating `pattern` of block kinds:

  'attn'        full-causal self-attention block (attn + mlp)
  'local'       sliding-window self-attention block
  'mamba'       Mamba2 SSD block
  'shared_attn' full-attention block whose params are SHARED across all
                occurrences (Zamba2-style shared transformer block)
  'cross'       self-attention + cross-attention (VLM) block

`n_layers` counts pattern-block instances; the stack is
``n_layers // len(pattern)`` scanned superblocks plus an unrolled
remainder of ``n_layers % len(pattern)`` leading pattern positions.
'shared_attn' positions do NOT count toward n_layers (they are extra,
weight-tied injections — Zamba semantics).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads

    # --- attention pattern -------------------------------------------------
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096                # sliding-window size for 'local'
    attn_logit_softcap: float = 0.0   # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0        # chatglm: 0.5 (2d RoPE)
    # long-context adaptation: in long_500k mode, 'attn' blocks become
    # 'local' with this window (0 → arch cannot run long_500k).
    long_context_window: int = 0
    # when > 0, the Zamba2-style shared attention block attends through a
    # sliding window of this size (set by .long_context()).
    shared_attn_window: int = 0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    # per-pattern-position MoE flag (llama4 alternates dense/MoE layers);
    # None → every attention-type block is MoE when n_experts > 0.
    moe_pattern: Optional[Tuple[bool, ...]] = None
    parallel_dense_mlp: bool = False  # llama4 shared expert / arctic dense residual
    capacity_factor: float = 1.25
    moe_group_size: int = 4096        # token-group size for capacity dispatch

    # --- SSM (Mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # --- VLM -------------------------------------------------------------------
    n_patches: int = 0                # vision-stub patch count

    # --- audio ------------------------------------------------------------------
    n_codebooks: int = 0              # EnCodec codebooks (musicgen: 4)

    # --- misc ---------------------------------------------------------------
    act: str = "silu"                 # silu | gelu
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True                # checkpoint superblocks in train_step
    efficient_ce: bool = False        # logsumexp CE (no fp32 logp tensor)
    attn_fp32_softmax: bool = True    # False → bf16 softmax tensors (the
                                      # Pallas flash kernel's on-chip
                                      # accumulator makes this moot on TPU)
    use_pallas_attention: bool = False  # route full-seq attention through
                                        # kernels/flash_attention (TPU
                                        # target; interpret=True on CPU)
    optimizer: str = "adam"
    learning_rate: float = 3e-4
    source: str = ""                  # citation from the assignment

    # ---------------------------------------------------------------------
    def use_moe(self, pattern_idx: int) -> bool:
        if not self.n_experts:
            return False
        if self.moe_pattern is None:
            return True
        return bool(self.moe_pattern[pattern_idx])

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def period(self) -> int:
        # 'shared_attn' occupies a pattern slot but not a layer count
        return sum(1 for k in self.pattern if k != "shared_attn")

    @property
    def n_super(self) -> int:
        return self.n_layers // self.period

    @property
    def n_rem(self) -> int:
        return self.n_layers % self.period

    @property
    def supports_long_context(self) -> bool:
        """True when every block is sub-quadratic at decode time (natively
        windowed/SSM, or adaptable via long_context_window)."""
        for k in self.pattern:
            if k in ("mamba", "local"):
                continue
            if k in ("attn", "shared_attn") and self.long_context_window > 0:
                continue
            return False
        return True

    @property
    def is_decoder(self) -> bool:
        return True  # all assigned archs are decoders (no encoder-only)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 layers worth of pattern, d_model ≤ 512,
        ≤4 experts — runnable on CPU in seconds."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads) or n_heads
        while n_heads % n_kv:
            n_kv -= 1
        period = self.period
        # keep one full pattern period (so every block kind is exercised)
        n_layers = period if period > 1 else 2
        return self.replace(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512), head_dim=None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # drop-free dispatch so batched vs single-token routing agree
            # exactly in the smoke tests (full configs keep 1.25)
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            window=min(self.window, 64),
            long_context_window=(min(self.long_context_window, 64)
                                 if self.long_context_window else 0),
            moe_group_size=64, remat=False, dtype="float32")

    def long_context(self) -> "ArchConfig":
        """Variant for long_500k: every full-attention block becomes a
        sliding-window block (DESIGN.md hardware-adaptation note)."""
        if not self.supports_long_context:
            raise ValueError(f"{self.name} cannot run long-context decode")
        w = self.long_context_window or self.window
        pat = tuple(("local" if k == "attn" else k) for k in self.pattern)
        shared_w = w if any(k == "shared_attn" for k in self.pattern) else 0
        return self.replace(pattern=pat, window=w if w else self.window,
                            shared_attn_window=shared_w)


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (for MODEL_FLOPS and sanity checks)."""
    D, F, V, hd = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.hd
    H, K = cfg.n_heads, cfg.n_kv_heads
    attn = D * H * hd + 2 * D * K * hd + H * hd * D  # q, k, v, o
    mlp = 3 * D * F                                   # gated: wg, wu, wd
    moe = cfg.n_experts * 3 * D * F + D * cfg.n_experts
    if cfg.parallel_dense_mlp:
        moe += mlp
    mamba = 0
    if cfg.ssm_state:
        din, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        conv_dim = din + 2 * N
        in_proj = D * (2 * din + 2 * N + Hs)
        mamba = in_proj + conv_dim * cfg.ssm_conv + 3 * Hs + din + din * D
    norms = 2 * D
    kinds = {"attn": attn + mlp + norms, "local": attn + mlp + norms,
             "cross": 2 * attn + mlp + 3 * D,
             "mamba": mamba + D,
             "shared_attn": 0}
    total = 0
    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    for li in range(cfg.n_layers):
        i = layer_positions[li % len(layer_positions)]
        kind = cfg.pattern[i]
        total += kinds[kind]
        if kind in ("attn", "local", "cross") and cfg.use_moe(i):
            total += moe - mlp
    if any(k == "shared_attn" for k in cfg.pattern):
        total += attn + mlp + norms  # one shared block
    total += V * D                     # embedding
    if not cfg.tie_embeddings:
        total += D * V * max(1, cfg.n_codebooks or 1)
    if cfg.n_codebooks:
        total += (cfg.n_codebooks - 1) * V * D  # extra codebook embeddings
    total += D  # final norm
    return total


def _pattern_layer_counts(cfg: ArchConfig) -> dict:
    counts: dict = {}
    pat = [k for k in cfg.pattern if k != "shared_attn"]
    for i in range(cfg.n_layers):
        kind = pat[i % len(pat)]
        counts[kind] = counts.get(kind, 0) + 1
    return counts
