from .config import ArchConfig, param_count
from .transformer import (decode_step, forward, init_cache, init_params,
                          loss_fn, make_train_step, prefill,
                          warm_cross_caches)

__all__ = ["ArchConfig", "param_count", "decode_step", "forward",
           "init_cache", "init_params", "loss_fn", "make_train_step",
           "prefill", "warm_cross_caches"]
