"""Mixture-of-Experts block: top-k routing with capacity-based dispatch.

TPU-idiomatic (einsum one-hot dispatch → dense expert matmuls, experts
sharded over the `model` mesh axis so the dispatch einsums lower to
all-to-all-style collectives).  Tokens are processed in groups via
lax.scan so the (g, E, C) dispatch tensor stays bounded regardless of
global token count.

Covers: llama4-maverick (128e top-1 + shared dense expert) and
arctic (128e top-2 + parallel dense-residual FFN) via
`cfg.parallel_dense_mlp`.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import gated_mlp, gated_mlp_init, he_init

Pytree = Any


def moe_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> Pytree:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    p = {"router": he_init(ks[0], (D, E), D, jnp.float32),  # router in fp32
         "wg": he_init(ks[1], (E, D, F), D, dtype),
         "wu": he_init(ks[2], (E, D, F), D, dtype),
         "wd": he_init(ks[3], (E, F, D), F, dtype)}
    if cfg.parallel_dense_mlp:
        p["dense"] = gated_mlp_init(ks[4], D, F, dtype)
    return p


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(group * top_k / n_experts * factor)
    return max(1, c)


def _dispatch_combine(logits: jnp.ndarray, top_k: int,
                      capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build (g,E,C) dispatch/combine tensors from router logits (g,E)."""
    g, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)            # (g, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((g, E, capacity), jnp.float32)
    combine = jnp.zeros((g, E, capacity), jnp.float32)
    # fill per routing choice; running per-expert occupancy across choices
    occupancy = jnp.zeros((E,), jnp.int32)
    for choice in range(top_k):
        e = topi[:, choice]                              # (g,)
        w = topv[:, choice]
        mask_e = jax.nn.one_hot(e, E, dtype=jnp.int32)   # (g, E)
        pos = jnp.cumsum(mask_e, axis=0) - 1 + occupancy[None, :]
        occupancy = occupancy + mask_e.sum(axis=0)
        pos_tok = jnp.take_along_axis(pos, e[:, None], axis=1)[:, 0]
        keep = pos_tok < capacity
        oh_e = jax.nn.one_hot(e, E, dtype=jnp.float32)
        oh_c = jax.nn.one_hot(jnp.where(keep, pos_tok, capacity),
                              capacity, dtype=jnp.float32)
        d = oh_e[:, :, None] * oh_c[:, None, :]
        dispatch = dispatch + d
        combine = combine + d * w[:, None, None]
    return dispatch, combine


def moe_block(p: Pytree, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """x: (B, S, D) → (B, S, D). Token groups scanned; experts dense."""
    B, S, D = x.shape
    T = B * S
    flat = x.reshape(T, D)
    g = min(cfg.moe_group_size, T)
    # pad so group count divides
    n_groups = -(-T // g)
    pad = n_groups * g - T
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, D), flat.dtype)])
    grouped = flat.reshape(n_groups, g, D)
    capacity = _capacity(g, cfg.top_k, cfg.n_experts, cfg.capacity_factor)

    router = p["router"].astype(jnp.float32)

    def per_group(_, xg):
        logits = xg.astype(jnp.float32) @ router              # (g, E)
        dispatch, combine = _dispatch_combine(logits, cfg.top_k, capacity)
        dispatch = dispatch.astype(xg.dtype)
        expert_in = jnp.einsum("gec,gd->ecd", dispatch, xg)
        a = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"].astype(xg.dtype))
        u = jnp.einsum("ecd,edf->ecf", expert_in, p["wu"].astype(xg.dtype))
        h = (jax.nn.silu(a) if cfg.act == "silu" else jax.nn.gelu(a)) * u
        expert_out = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(xg.dtype))
        yg = jnp.einsum("gec,ecd->gd", combine.astype(xg.dtype), expert_out)
        return None, yg

    if n_groups == 1:
        _, y = per_group(None, grouped[0])
        y = y[None]
    else:
        _, y = jax.lax.scan(per_group, None, grouped)
    y = y.reshape(n_groups * g, D)[:T].reshape(B, S, D)

    if cfg.parallel_dense_mlp:
        y = y + gated_mlp(p["dense"], x, cfg.act)
    return y


def router_load(p: Pytree, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Per-expert token counts (diagnostics / load-balance tests)."""
    logits = x.reshape(-1, x.shape[-1]).astype(jnp.float32) @ \
        p["router"].astype(jnp.float32)
    _, topi = jax.lax.top_k(logits, cfg.top_k)
    return jnp.bincount(topi.reshape(-1), length=cfg.n_experts)
