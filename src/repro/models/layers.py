"""Shared neural layers: norms, gated MLP, RoPE, embeddings, init."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ----------------------------------------------------------------- init
def he_init(rng, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fan_in = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    scale = jnp.sqrt(2.0 / max(1, fan_in))
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return (jax.random.normal(rng, shape) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """RMSNorm in fp32, cast back to input dtype (gemma-style 1+scale)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ----------------------------------------------------------------- MLP
def gated_mlp_init(rng, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"wg": he_init(k1, (d_model, d_ff), d_model, dtype),
            "wu": he_init(k2, (d_model, d_ff), d_model, dtype),
            "wd": he_init(k3, (d_ff, d_model), d_ff, dtype)}


def gated_mlp(p: Pytree, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """SwiGLU/GeGLU: down( act(x@wg) * (x@wu) )."""
    a = jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["wu"].astype(x.dtype))
    h = (jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)) * u
    return jnp.einsum("...f,fd->...d", h, p["wd"].astype(x.dtype))


# ----------------------------------------------------------------- RoPE
def rope_frequencies(hd: int, fraction: float, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotated sub-dimension (fraction of hd)."""
    rot = int(hd * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, fraction: float,
               theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, hd); positions: broadcastable to (..., S).

    Applies rotary embedding to the first `fraction·hd` dims and passes the
    rest through (chatglm3's 2d/partial RoPE uses fraction=0.5).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    inv = rope_frequencies(hd, fraction, theta)                   # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (...,S,rot/2)
    cos = jnp.cos(ang)[..., None, :]                              # add head dim
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


# ----------------------------------------------------------------- misc
def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma2 logit soft-capping: cap · tanh(x / cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, targets: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None,
                       impl: str = "logsoftmax") -> jnp.ndarray:
    """Mean token CE in fp32. logits (..., V), targets (...) int.

    impl='logsumexp' avoids materialising the full fp32 log-softmax tensor
    (nll = logsumexp(logits) − logits[target]) — mathematically identical,
    ~half the HBM traffic on large-vocab models (§Perf hillclimb).
    """
    if impl == "logsumexp":
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        picked = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
        nll = lse - picked
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(1.0, jnp.sum(mask))
    return jnp.mean(nll)
