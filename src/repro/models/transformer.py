"""Unified decoder backbone covering all six assigned arch types.

The layer stack is `lax.scan` over superblocks (one repetition of
cfg.pattern) with stacked params — compile time and HLO size stay bounded
for 26–48-layer models.  A remainder of n_layers % period pattern
positions is unrolled with unstacked params.  'shared_attn' blocks
(Zamba2) hold one weight-tied param set used at every occurrence.

Entry points:
  init_params(cfg, rng)                       → params
  forward(cfg, params, batch)                 → logits           (train/eval)
  prefill(cfg, params, batch)                 → (logits, cache)  (prefill)
  decode_step(cfg, params, cache, tokens, pos)→ (logits, cache)  (decode)
  init_cache(cfg, batch_size, context_len)    → cache pytree
  make_train_step(cfg)                        → jit-able train step
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..optim import apply_updates, make_optimizer
from .attention import (attn_init, cross_attention, decode_cross_attention,
                        decode_self_attention, init_cross_cache,
                        init_kv_cache, kv_to_cache, self_attention)
from .config import ArchConfig
from .layers import (cross_entropy_loss, dtype_of, embed_init, gated_mlp,
                     gated_mlp_init, he_init, rms_norm, softcap)
from .moe import moe_block, moe_init
from .ssm import init_mamba_cache, mamba_block, mamba_decode_step, mamba_init

Pytree = Any


# ============================================================ param init
def _block_init(rng, kind: str, cfg: ArchConfig, dtype,
                use_moe: bool = False) -> Pytree:
    ks = jax.random.split(rng, 6)
    D = cfg.d_model
    if kind == "mamba":
        return {"ln1": jnp.zeros((D,), dtype),
                "mamba": mamba_init(ks[0], cfg, dtype)}
    p = {"ln1": jnp.zeros((D,), dtype),
         "attn": attn_init(ks[0], cfg, dtype),
         "ln2": jnp.zeros((D,), dtype)}
    if use_moe and kind in ("attn", "local", "cross"):
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = gated_mlp_init(ks[1], D, cfg.d_ff, dtype)
    if kind == "cross":
        p["lnx"] = jnp.zeros((D,), dtype)
        p["xattn"] = attn_init(ks[2], cfg, dtype)
        p["xgate"] = jnp.zeros((1,), jnp.float32)
    return p


def init_params(cfg: ArchConfig, rng) -> Pytree:
    dtype = dtype_of(cfg.param_dtype)
    D, V = cfg.d_model, cfg.vocab
    ks = jax.random.split(rng, 8 + len(cfg.pattern))
    params: Dict[str, Any] = {}

    if cfg.n_codebooks:
        params["embed"] = embed_init(ks[0], (cfg.n_codebooks, V, D), dtype)
    else:
        params["embed"] = embed_init(ks[0], (V, D), dtype)

    # scanned superblocks: stack n_super copies per pattern position
    blocks: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "shared_attn":
            continue
        key = jax.random.fold_in(ks[1], i)
        stack = [_block_init(jax.random.fold_in(key, s), kind, cfg, dtype,
                             cfg.use_moe(i))
                 for s in range(cfg.n_super)]
        blocks[f"pos{i}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *stack) if cfg.n_super > 1 else \
            jax.tree_util.tree_map(lambda x: x[None], stack[0])
    params["blocks"] = blocks

    # unrolled remainder
    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    rem = {}
    for j in range(cfg.n_rem):
        i = layer_positions[j]
        rem[f"pos{i}"] = _block_init(jax.random.fold_in(ks[2], j),
                                     cfg.pattern[i], cfg, dtype,
                                     cfg.use_moe(i))
    if rem:
        params["rem"] = rem

    if any(k == "shared_attn" for k in cfg.pattern):
        params["shared_attn"] = _block_init(ks[3], "attn", cfg, dtype)

    params["final_norm"] = jnp.zeros((D,), dtype)
    if not cfg.tie_embeddings:
        out = V * max(1, cfg.n_codebooks)
        params["head"] = he_init(ks[4], (D, out), D, dtype)
    return params


# ============================================================ block fwd
def _apply_block(kind: str, p: Pytree, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray, window_override: Optional[int],
                 image_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    if kind == "mamba":
        return x + mamba_block(p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps),
                               cfg)
    window = cfg.window if kind == "local" else None
    if window_override is not None and kind in ("attn", "shared_attn"):
        window = window_override
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + self_attention(p["attn"], h, positions, cfg, window)
    if kind == "cross" and image_embeds is not None:
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        gate = jnp.tanh(p["xgate"]).astype(x.dtype)
        x = x + gate * cross_attention(p["xattn"], hx, image_embeds, cfg)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        return x + moe_block(p["moe"], h2, cfg)
    return x + gated_mlp(p["mlp"], h2, cfg.act)


def _superblock(params_i: Pytree, shared: Optional[Pytree], x: jnp.ndarray,
                cfg: ArchConfig, positions: jnp.ndarray,
                image_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    for i, kind in enumerate(cfg.pattern):
        if kind == "shared_attn":
            x = _apply_block("attn", shared, x, cfg, positions,
                             cfg.shared_attn_window or None, image_embeds)
        else:
            x = _apply_block(kind, params_i[f"pos{i}"], x, cfg, positions,
                             None, image_embeds)
    return x


# ============================================================ embeddings
def _embed(cfg: ArchConfig, params: Pytree, tokens: jnp.ndarray,
           dtype) -> jnp.ndarray:
    if cfg.n_codebooks:
        # tokens: (B, n_cb, S) → sum of per-codebook embeddings
        embs = [params["embed"][c][tokens[:, c, :]]
                for c in range(cfg.n_codebooks)]
        return sum(embs).astype(dtype)
    return params["embed"][tokens].astype(dtype)


def _logits(cfg: ArchConfig, params: Pytree, h: jnp.ndarray) -> jnp.ndarray:
    if not cfg.tie_embeddings and "head" in params:
        out = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))
    elif cfg.n_codebooks:
        out = jnp.einsum("bsd,cvd->bscv", h, params["embed"].astype(h.dtype))
        out = out.reshape(*h.shape[:2], cfg.n_codebooks * cfg.vocab)
    else:
        out = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return softcap(out, cfg.final_logit_softcap)


# ============================================================ forward
def forward(cfg: ArchConfig, params: Pytree, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    """Full-sequence forward → logits (B, S, V[*n_cb])."""
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    B = tokens.shape[0]
    S = tokens.shape[-1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    image_embeds = batch.get("image_embeds")
    if image_embeds is not None:
        image_embeds = image_embeds.astype(dtype)

    x = _embed(cfg, params, tokens, dtype)
    shared = params.get("shared_attn")

    body = partial(_superblock, shared=shared, cfg=cfg, positions=positions,
                   image_embeds=image_embeds)

    def scan_fn(x, params_i):
        f = (jax.checkpoint(lambda pi, xx: body(pi, x=xx))
             if cfg.remat else (lambda pi, xx: body(pi, x=xx)))
        return f(params_i, x), None

    if cfg.n_super > 0:
        x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    for j in range(cfg.n_rem):
        i = layer_positions[j]
        x = _apply_block(cfg.pattern[i], params["rem"][f"pos{i}"], x, cfg,
                         positions, None, image_embeds)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x)


# ============================================================ loss / train
def loss_fn(cfg: ArchConfig, params: Pytree,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    logits = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.n_codebooks:
        B, S = labels.shape[0], labels.shape[-1]
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab)
        logits = jnp.swapaxes(logits, 1, 2)  # (B, n_cb, S, V)
    return cross_entropy_loss(
        logits, labels,
        impl="logsumexp" if cfg.efficient_ce else "logsoftmax")


def make_train_step(cfg: ArchConfig):
    """Returns (train_step, init_state). State = {'params', 'opt'}."""
    optimizer = make_optimizer(cfg.optimizer, cfg.learning_rate)

    def init_state(rng) -> Pytree:
        params = init_params(cfg, rng)
        return {"params": params, "opt": optimizer.init(params)}

    def train_step(state: Pytree, batch: Dict[str, jnp.ndarray]) -> Tuple:
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch))(state["params"])
        updates, opt = optimizer.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        return {"params": params, "opt": opt}, loss

    return train_step, init_state


# ============================================================ caches
def _block_cache(kind: str, cfg: ArchConfig, batch: int, context: int,
                 dtype=jnp.bfloat16) -> Pytree:
    if kind == "mamba":
        return init_mamba_cache(cfg, batch, jnp.float32)
    if kind == "local":
        length = min(cfg.window, context)
    elif kind == "shared_attn" and cfg.shared_attn_window:
        length = min(cfg.shared_attn_window, context)
    else:
        length = context
    c = init_kv_cache(cfg, batch, length, dtype)
    if kind == "cross":
        c["ck"] = jnp.zeros((batch, cfg.n_patches, cfg.n_kv_heads, cfg.hd),
                            dtype)
        c["cv"] = jnp.zeros((batch, cfg.n_patches, cfg.n_kv_heads, cfg.hd),
                            dtype)
    return c


def init_cache(cfg: ArchConfig, batch: int, context: int,
               dtype=jnp.bfloat16) -> Pytree:
    """Zero-initialised cache pytree matching decode_step's expectations."""
    def stack(tree, n):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)

    cache: Dict[str, Any] = {"blocks": {}}
    for i, kind in enumerate(cfg.pattern):
        blk = _block_cache(kind, cfg, batch, context, dtype)
        cache["blocks"][f"pos{i}"] = stack(blk, max(1, cfg.n_super))
    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    rem = {}
    for j in range(cfg.n_rem):
        i = layer_positions[j]
        rem[f"pos{i}"] = _block_cache(cfg.pattern[i], cfg, batch, context,
                                      dtype)
    if rem:
        cache["rem"] = rem
    return cache


def warm_cross_caches(cfg: ArchConfig, params: Pytree, cache: Pytree,
                      image_embeds: jnp.ndarray) -> Pytree:
    """Populate cross-attn K/V from vision features (before decoding)."""
    dtype = dtype_of(cfg.dtype)
    feats = image_embeds.astype(dtype)
    new_blocks = dict(cache["blocks"])
    for i, kind in enumerate(cfg.pattern):
        if kind != "cross":
            continue
        xattn_stack = params["blocks"][f"pos{i}"]["xattn"]
        def per_super(pw):
            return init_cross_cache(pw, feats, dtype)
        cc = jax.vmap(per_super)(xattn_stack)
        ent = dict(cache["blocks"][f"pos{i}"])
        ent["ck"], ent["cv"] = cc["ck"], cc["cv"]
        new_blocks[f"pos{i}"] = ent
    out = dict(cache)
    out["blocks"] = new_blocks
    return out


# ============================================================ prefill
def _prefill_block(kind: str, p: Pytree, x: jnp.ndarray, cfg: ArchConfig,
                   positions: jnp.ndarray,
                   image_embeds: Optional[jnp.ndarray], cache_dtype,
                   cache_len: int,
                   window_override: Optional[int] = None
                   ) -> Tuple[jnp.ndarray, Pytree]:
    if kind == "mamba":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, c = mamba_block(p["mamba"], h, cfg, return_cache=True)
        return x + y, c
    window = cfg.window if kind == "local" else window_override
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, (k, v) = self_attention(p["attn"], h, positions, cfg, window,
                               return_kv=True)
    x = x + y
    kc, vc = kv_to_cache(k, v, window, cache_dtype)
    if not window and cache_len > kc.shape[2]:
        pad = cache_len - kc.shape[2]
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    c = {"k": kc, "v": vc}
    if kind == "cross" and image_embeds is not None:
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        gate = jnp.tanh(p["xgate"]).astype(x.dtype)
        x = x + gate * cross_attention(p["xattn"], hx, image_embeds, cfg)
        cc = init_cross_cache(p["xattn"], image_embeds, cache_dtype)
        c["ck"], c["cv"] = cc["ck"], cc["cv"]
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe_block(p["moe"], h2, cfg)
    else:
        x = x + gated_mlp(p["mlp"], h2, cfg.act)
    return x, c


def prefill(cfg: ArchConfig, params: Pytree, batch: Dict[str, jnp.ndarray],
            cache_len: Optional[int] = None,
            cache_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Pytree]:
    """Inference prefill: full-sequence forward that also emits the decode
    cache (KV per attention block in ring/linear layout, SSM states for
    Mamba blocks, cross-attn K/V for VLM blocks)."""
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape[0], tokens.shape[-1]
    cache_len = cache_len or S
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    image_embeds = batch.get("image_embeds")
    if image_embeds is not None:
        image_embeds = image_embeds.astype(dtype)

    x = _embed(cfg, params, tokens, dtype)
    shared = params.get("shared_attn")

    def scan_fn(x, params_i):
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            if kind == "shared_attn":
                x, c = _prefill_block(
                    "attn", shared, x, cfg, positions, image_embeds,
                    cache_dtype, cache_len,
                    cfg.shared_attn_window or None)
            else:
                x, c = _prefill_block(
                    kind, params_i[f"pos{i}"], x, cfg, positions,
                    image_embeds, cache_dtype, cache_len)
            new_cache[f"pos{i}"] = c
        return x, new_cache

    cache: Dict[str, Any] = {}
    if cfg.n_super > 0:
        x, blocks_cache = jax.lax.scan(scan_fn, x, params["blocks"])
        cache["blocks"] = blocks_cache
    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    rem = {}
    for j in range(cfg.n_rem):
        i = layer_positions[j]
        x, c = _prefill_block(cfg.pattern[i], params["rem"][f"pos{i}"], x,
                              cfg, positions, image_embeds, cache_dtype,
                              cache_len)
        rem[f"pos{i}"] = c
    if rem:
        cache["rem"] = rem

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x), cache


# ============================================================ decode
def _decode_block(kind: str, p: Pytree, x: jnp.ndarray, blk_cache: Pytree,
                  pos: jnp.ndarray, cfg: ArchConfig,
                  window_override: Optional[int] = None
                  ) -> Tuple[jnp.ndarray, Pytree]:
    if kind == "mamba":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_cache = mamba_decode_step(p["mamba"], h, blk_cache, cfg)
        return x + y, new_cache
    window = cfg.window if kind == "local" else window_override
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kv = {"k": blk_cache["k"], "v": blk_cache["v"]}
    y, kv = decode_self_attention(p["attn"], h, kv, pos, cfg, window)
    x = x + y
    new_cache = dict(blk_cache)
    new_cache.update(kv)
    if kind == "cross":
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        gate = jnp.tanh(p["xgate"]).astype(x.dtype)
        cc = {"ck": blk_cache["ck"], "cv": blk_cache["cv"]}
        x = x + gate * decode_cross_attention(p["xattn"], hx, cc, cfg)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe_block(p["moe"], h2, cfg)
    else:
        x = x + gated_mlp(p["mlp"], h2, cfg.act)
    return x, new_cache


def decode_step(cfg: ArchConfig, params: Pytree, cache: Pytree,
                tokens: jnp.ndarray, pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Pytree]:
    """One decode step. tokens: (B, 1) (audio: (B, n_cb, 1)); pos: (B,)."""
    dtype = dtype_of(cfg.dtype)
    x = _embed(cfg, params, tokens, dtype)
    shared = params.get("shared_attn")

    def superblock_dec(x, params_i, cache_i):
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            ci = cache_i[f"pos{i}"]
            if kind == "shared_attn":
                x, nc = _decode_block("attn", shared, x, ci, pos, cfg,
                                      cfg.shared_attn_window or None)
            else:
                x, nc = _decode_block(kind, params_i[f"pos{i}"], x, ci, pos,
                                      cfg)
            new_cache[f"pos{i}"] = nc
        return x, new_cache

    def scan_fn(x, inp):
        params_i, cache_i = inp
        return superblock_dec(x, params_i, cache_i)

    if cfg.n_super > 0:
        x, new_blocks = jax.lax.scan(scan_fn, x,
                                     (params["blocks"], cache["blocks"]))
    else:
        new_blocks = cache["blocks"]

    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    new_rem = {}
    for j in range(cfg.n_rem):
        i = layer_positions[j]
        x, nc = _decode_block(cfg.pattern[i], params["rem"][f"pos{i}"], x,
                              cache["rem"][f"pos{i}"], pos, cfg)
        new_rem[f"pos{i}"] = nc

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x)
    out_cache: Dict[str, Any] = {"blocks": new_blocks}
    if new_rem:
        out_cache["rem"] = new_rem
    return logits, out_cache
