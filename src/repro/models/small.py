"""The paper's client model architectures (§VI-A2), from scratch in JAX.

- MNIST:    2×[conv5x5 + maxpool2x2] → FC(512) → FC(10)
- FEMNIST:  2×[conv5x5 + maxpool2x2] → FC(2048) → FC(62)
- Shakespeare: embed(8) → 2×LSTM(256) → FC(82)
- Speech:   2×[conv3x3, conv3x3, maxpool, dropout(.25)] → avgpool → FC(35)

Functional (init, apply) pairs; params are plain dict pytrees.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

Pytree = Any


class ModelDef(NamedTuple):
    init: Callable[..., Pytree]
    apply: Callable[..., jnp.ndarray]
    name: str


# ---------------------------------------------------------------- helpers
def _dense_init(rng, n_in, n_out):
    k1, _ = jax.random.split(rng)
    scale = jnp.sqrt(2.0 / n_in)
    return {"w": jax.random.normal(k1, (n_in, n_out)) * scale,
            "b": jnp.zeros((n_out,))}


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _conv_init(rng, kh, kw, cin, cout):
    scale = jnp.sqrt(2.0 / (kh * kw * cin))
    return {"w": jax.random.normal(rng, (kh, kw, cin, cout)) * scale,
            "b": jnp.zeros((cout,))}


def _conv(p, x):  # NHWC, SAME padding
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, k=2):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, k, k, 1),
                             (1, k, k, 1), "VALID")


# ---------------------------------------------------------------- CNNs
def make_cnn(image_size: int = 28, channels: int = 1, n_classes: int = 10,
             fc_width: int = 512, name: str = "mnist_cnn") -> ModelDef:
    """The paper's LEAF-style 2-layer 5x5 CNN (MNIST: fc=512/10 classes,
    FEMNIST: fc=2048/62 classes)."""
    pooled = image_size // 4  # two 2x2 maxpools

    def init(rng):
        ks = jax.random.split(rng, 4)
        return {
            "conv1": _conv_init(ks[0], 5, 5, channels, 32),
            "conv2": _conv_init(ks[1], 5, 5, 32, 64),
            "fc1": _dense_init(ks[2], pooled * pooled * 64, fc_width),
            "out": _dense_init(ks[3], fc_width, n_classes),
        }

    def apply(params, x):
        h = jax.nn.relu(_conv(params["conv1"], x))
        h = _maxpool(h)
        h = jax.nn.relu(_conv(params["conv2"], h))
        h = _maxpool(h)
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(_dense(params["fc1"], h))
        return _dense(params["out"], h)

    return ModelDef(init, apply, name)


# ---------------------------------------------------------------- LSTM
def _lstm_init(rng, n_in, hidden):
    k1, k2 = jax.random.split(rng)
    s_in = jnp.sqrt(1.0 / n_in)
    s_h = jnp.sqrt(1.0 / hidden)
    return {"wx": jax.random.normal(k1, (n_in, 4 * hidden)) * s_in,
            "wh": jax.random.normal(k2, (hidden, 4 * hidden)) * s_h,
            "b": jnp.zeros((4 * hidden,))}


def _lstm_scan(p, xs):
    """xs: (B, T, n_in) → outputs (B, T, hidden)."""
    hidden = p["wh"].shape[0]
    B = xs.shape[0]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, hidden), xs.dtype), jnp.zeros((B, hidden), xs.dtype))
    (_, _), out = lax.scan(step, init, jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(out, 0, 1)


def make_char_lstm(vocab: int = 82, embed: int = 8,
                   hidden: int = 256, name: str = "shakespeare_lstm") -> ModelDef:
    """embed(8) → LSTM(256) ×2 → FC(vocab): predict next char from 80 chars."""

    def init(rng):
        ks = jax.random.split(rng, 4)
        return {
            "embed": jax.random.normal(ks[0], (vocab, embed)) * 0.1,
            "lstm1": _lstm_init(ks[1], embed, hidden),
            "lstm2": _lstm_init(ks[2], hidden, hidden),
            "out": _dense_init(ks[3], hidden, vocab),
        }

    def apply(params, tokens):  # (B, T) int32 → (B, vocab)
        h = params["embed"][tokens]
        h = _lstm_scan(params["lstm1"], h)
        h = _lstm_scan(params["lstm2"], h)
        return _dense(params["out"], h[:, -1, :])

    return ModelDef(init, apply, name)


# ---------------------------------------------------------------- speech
def make_speech_cnn(frames: int = 32, mels: int = 32, n_classes: int = 35,
                    name: str = "speech_cnn") -> ModelDef:
    """Paper §VI-A2: two blocks of [conv3x3, conv3x3, maxpool, dropout] →
    average pool → FC(35).  Dropout is inference-scaled (applied only when
    a dropout rng is passed)."""

    def init(rng):
        ks = jax.random.split(rng, 5)
        return {
            "c1a": _conv_init(ks[0], 3, 3, 1, 32),
            "c1b": _conv_init(ks[1], 3, 3, 32, 32),
            "c2a": _conv_init(ks[2], 3, 3, 32, 64),
            "c2b": _conv_init(ks[3], 3, 3, 64, 64),
            "out": _dense_init(ks[4], 64, n_classes),
        }

    def apply(params, x, *, dropout_rng=None, rate: float = 0.25):
        def block(h, pa, pb):
            h = jax.nn.relu(_conv(pa, h))
            h = jax.nn.relu(_conv(pb, h))
            h = _maxpool(h)
            if dropout_rng is not None:
                keep = jax.random.bernoulli(dropout_rng, 1 - rate, h.shape)
                h = jnp.where(keep, h / (1 - rate), 0.0)
            return h

        h = block(x, params["c1a"], params["c1b"])
        h = block(h, params["c2a"], params["c2b"])
        h = h.mean(axis=(1, 2))  # global average pool
        return _dense(params["out"], h)

    return ModelDef(init, apply, name)


SMALL_MODELS = {
    "mnist_cnn": lambda: make_cnn(28, 1, 10, 512, "mnist_cnn"),
    "femnist_cnn": lambda: make_cnn(28, 1, 62, 2048, "femnist_cnn"),
    "shakespeare_lstm": lambda: make_char_lstm(),
    "speech_cnn": lambda: make_speech_cnn(),
}
