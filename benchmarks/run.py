"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_fig1        — FedAvg round duration & accuracy vs straggler %
  * bench_table2      — accuracy + EUR per strategy × straggler ratio
  * bench_table3      — experiment duration per strategy × ratio
  * bench_table4      — cost per strategy × ratio
  * bench_fig3c       — selection-bias distribution per strategy
  * bench_cost_attr   — per-client cost concentration (CostMeter breakdown)
  * bench_async       — sync vs semi-async vs FedAsync/FedBuff + traces
  * bench_kernels     — Pallas kernel µs/call vs jnp oracle µs/call
  * bench_roofline    — dry-run roofline terms per (arch × shape) [cached]

Run: ``PYTHONPATH=src python -m benchmarks.run``
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.fedless_grid import (RATIOS, STRATEGIES, run_async_grid,
                                     run_grid)

RESULTS = Path(__file__).resolve().parent.parent / "results"


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def _time_call(fn, n: int = 5) -> float:
    out = fn()  # warmup / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------- fig 1
def bench_fig1(grid: dict) -> None:
    """Paper Fig. 1: FedAvg accuracy & mean round duration vs straggler %."""
    for ratio in RATIOS:
        g = grid[f"fedavg@{ratio}"]
        mean_round = float(np.mean(g["round_durations"]))
        _row(f"fig1/fedavg_stragglers_{int(ratio*100)}pct",
             mean_round * 1e6,
             f"acc={g['accuracy']:.3f};round_s={mean_round:.1f}")


# ---------------------------------------------------------------- table 2
def bench_table2(grid: dict) -> None:
    for s in STRATEGIES:
        for ratio in RATIOS:
            g = grid[f"{s}@{ratio}"]
            _row(f"table2/{s}_{int(ratio*100)}pct", 0.0,
                 f"acc={g['accuracy']:.3f};eur={g['eur']:.2f}")


# ---------------------------------------------------------------- table 3
def bench_table3(grid: dict) -> None:
    for s in STRATEGIES:
        for ratio in RATIOS:
            g = grid[f"{s}@{ratio}"]
            _row(f"table3/{s}_{int(ratio*100)}pct", g["duration_s"] * 1e6,
                 f"duration_s={g['duration_s']:.1f}")


# ---------------------------------------------------------------- table 4
def bench_table4(grid: dict) -> None:
    for s in STRATEGIES:
        for ratio in RATIOS:
            g = grid[f"{s}@{ratio}"]
            _row(f"table4/{s}_{int(ratio*100)}pct", 0.0,
                 f"cost_usd={g['cost_usd']:.4f}")


# ---------------------------------------------------------------- fig 3c
def bench_fig3c(grid: dict) -> None:
    """Selection bias: min/median/max invocations per client."""
    for s in STRATEGIES:
        g = grid[f"{s}@0.5"]
        inv = g["invocations"]
        _row(f"fig3c/{s}_50pct", 0.0,
             f"bias={g['bias']};min={min(inv)};med={int(np.median(inv))};"
             f"max={max(inv)}")


# ---------------------------------------------------------------- cost attribution
def bench_cost_attribution(grid: dict) -> None:
    """Per-client cost concentration at 50% stragglers: stragglers re-billed
    for whole rounds dominate the bill (CostMeter.by_client breakdown)."""
    for s in STRATEGIES:
        g = grid[f"{s}@0.5"]
        by_client = g.get("cost_by_client")
        if not by_client:
            _row(f"cost_attr/{s}_50pct", 0.0, "stale_cache=regen_grid")
            continue
        costs = sorted(by_client.values(), reverse=True)
        top3 = sum(costs[:3])
        total = sum(costs) or 1.0
        _row(f"cost_attr/{s}_50pct", 0.0,
             f"top3_share={top3 / total:.2f};clients_billed={len(costs)}")


# ---------------------------------------------------------------- async modes
def bench_async() -> None:
    """Training-mode comparison (sync / semi-async / barrier-free) at 30%
    stragglers, traces exported to results/traces/*.jsonl."""
    for name, g in run_async_grid().items():
        _row(f"async/{name}", g["duration_s"] * 1e6,
             f"mode={g['mode']};acc={g['accuracy']:.3f};eur={g['eur']:.2f};"
             f"cost_usd={g['cost_usd']:.4f};"
             f"updates={g['updates_delivered']}")


# ---------------------------------------------------------------- kernels
def bench_kernels() -> None:
    from repro.kernels import fed_agg, flash_attention, ssd_scan
    from repro.kernels.ref import fed_agg_ref, flash_attention_ref, ssd_ref
    rng = np.random.default_rng(0)

    u = jnp.asarray(rng.normal(size=(16, 1 << 16)), jnp.float32)
    c = jnp.asarray(rng.random(16), jnp.float32)
    us_k = _time_call(lambda: fed_agg(u, c))
    us_r = _time_call(lambda: fed_agg_ref(u, c))
    _row("kernels/fed_agg_16x65536", us_k, f"ref_us={us_r:.1f}")

    q = jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    us_k = _time_call(lambda: flash_attention(q, k, v, bq=128, bk=128))
    us_r = _time_call(lambda: flash_attention_ref(q, k, v))
    _row("kernels/flash_attention_512", us_k,
         f"ref_us={us_r:.1f};interpret=True")

    x = jnp.asarray(rng.normal(size=(1, 512, 4, 32)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(1, 512, 4))) * 0.3, jnp.float32)
    B = jnp.asarray(rng.normal(size=(1, 512, 4, 16)) * 0.5, jnp.float32)
    C = jnp.asarray(rng.normal(size=(1, 512, 4, 16)) * 0.5, jnp.float32)
    us_k = _time_call(lambda: ssd_scan(x, a, B, C, chunk=128))
    us_r = _time_call(lambda: ssd_ref(x, a, B, C))
    _row("kernels/ssd_scan_512", us_k, f"ref_us={us_r:.1f};interpret=True")


# ---------------------------------------------------------------- roofline
def bench_roofline() -> None:
    """Surface the dry-run roofline table (results/dryrun/*.json)."""
    ddir = RESULTS / "dryrun"
    if not ddir.exists():
        _row("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for f in sorted(ddir.glob("*__single.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            _row(f"roofline/{d['arch']}__{d['shape']}", 0.0,
                 f"status={d.get('status')}")
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        _row(f"roofline/{d['arch']}__{d['shape']}", bound * 1e6,
             f"dominant={r['dominant']};compute_s={r['compute_s']:.2e};"
             f"memory_s={r['memory_s']:.2e};"
             f"collective_s={r['collective_s']:.2e};"
             f"useful={r['useful_flops_ratio']:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    grid = run_grid()
    bench_fig1(grid)
    bench_table2(grid)
    bench_table3(grid)
    bench_table4(grid)
    bench_fig3c(grid)
    bench_cost_attribution(grid)
    bench_async()
    bench_kernels()
    bench_roofline()
    # beyond-paper: component ablations of FedLesScan
    from benchmarks.ablations import run_ablations
    for key, d in run_ablations().items():
        _row(f"ablation/{key}", 0.0,
             f"acc={d['accuracy']:.3f};eur={d['eur']:.2f};"
             f"time_s={d['duration_s']:.0f};cost={d['cost_usd']:.4f}")


if __name__ == "__main__":
    main()
