"""Beyond-paper ablations of FedLesScan's components.

The paper fixes τ=2 and always uses clustering+cooldown; here we isolate
each mechanism's contribution under a 50%-straggler scenario:

  * tau sweep (1, 2, 4)       — staleness window of Eq. 3
  * no-clustering             — tier system + cooldown but random choice
                                among participants (ablates DBSCAN)
  * no-late-updates           — selection only; late updates discarded
                                (ablates the semi-async store, §V-D)

  PYTHONPATH=src python -m benchmarks.ablations
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.selection import select_random
from repro.core.strategies import FedLesScan
from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn

CACHE = Path(__file__).resolve().parent.parent / "results" / "ablations.json"


class FedLesScanNoClustering(FedLesScan):
    """Tier priority + cooldown + staleness aggregation, but participants
    are drawn uniformly (no DBSCAN) — isolates the clustering benefit."""
    name = "fedlesscan-nocluster"

    def select(self, client_ids, round_number):
        rookies, participants, stragglers = self.history.partition(client_ids)
        need = self.config.clients_per_round
        chosen = [r.client_id for r in rookies][:need]
        pool = [p.client_id for p in participants]
        if len(chosen) < need and pool:
            take = min(need - len(chosen), len(pool))
            chosen += list(self.rng.choice(pool, size=take, replace=False))
        spool = [s.client_id for s in stragglers]
        if len(chosen) < need and spool:
            take = min(need - len(chosen), len(spool))
            chosen += list(self.rng.choice(spool, size=take, replace=False))
        return chosen


class FedLesScanNoLate(FedLesScan):
    """Clustering selection but stale updates are never aggregated."""
    name = "fedlesscan-nolate"
    semi_async = False

    def aggregate(self, updates, round_number, now=None,
                  global_params=None):
        from repro.core.aggregation import staleness_aggregate
        if not updates:
            return global_params
        return staleness_aggregate(list(updates), round_number,
                                   tau=self.config.tau)


def _setup(seed=0):
    full = make_image_classification(2400, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:2000], full.y[:2000])
    test = ArrayDataset(full.x[2000:], full.y[2000:])
    parts = label_sorted_shards(train, 24, 2, seed=seed)
    test_parts = label_sorted_shards(test, 24, 2, seed=seed)
    task = ClassificationTask(
        make_cnn(14, 1, 5, 64, "abl_cnn"),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


def run_ablations(force: bool = False) -> dict:
    if CACHE.exists() and not force:
        return json.loads(CACHE.read_text())
    from repro.core.strategies import STRATEGIES
    STRATEGIES.setdefault("fedlesscan-nocluster", FedLesScanNoClustering)
    STRATEGIES.setdefault("fedlesscan-nolate", FedLesScanNoLate)

    task, parts, test_parts = _setup()
    out = {}
    cases = ([("fedlesscan", {"tau": t}) for t in (1, 2, 4)]
             + [("fedlesscan-nocluster", {"tau": 2}),
                ("fedlesscan-nolate", {"tau": 2})])
    for strategy, overrides in cases:
        cfg = ExperimentConfig(
            strategy=strategy, n_rounds=14, clients_per_round=6,
            eval_every=0, seed=0, tau=overrides.get("tau", 2),
            scenario=ScenarioConfig(straggler_fraction=0.6,
                                    slow_share=1.0, slow_factor=4.0,
                                    slow_factor_jitter=3.0,
                                    round_timeout_s=45.0, seed=0))
        res = run_experiment(task, parts, test_parts, cfg)
        key = f"{strategy}/tau={cfg.tau}"
        out[key] = {"accuracy": res.final_accuracy, "eur": res.mean_eur,
                    "duration_s": res.total_duration_s,
                    "cost_usd": res.total_cost, "bias": res.bias}
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(out, indent=1))
    return out


def main() -> None:
    print("name,us_per_call,derived")
    for key, d in run_ablations().items():
        print(f"ablation/{key},0.0,"
              f"acc={d['accuracy']:.3f};eur={d['eur']:.2f};"
              f"time_s={d['duration_s']:.0f};cost={d['cost_usd']:.4f}")


if __name__ == "__main__":
    main()
