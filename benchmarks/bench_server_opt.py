"""Server-optimizer benchmark — 4 merge-pipeline optimizers × 3
straggler ratios.

Companion to ``bench_scheduler.py``: every cell runs the same semi-async
FedLesScan experiment on the same seed/task/straggler profile and varies
only the `MergePipeline`'s server optimizer (core/merge.py), so the JSON
isolates the server-side update rule's contribution to accuracy under
increasingly noisy, staleness-damped pseudo-gradients.  Results land in
``results/BENCH_server_opt.json`` (uploaded as a CI artifact).

Run: ``PYTHONPATH=src python -m benchmarks.bench_server_opt``
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn

SERVER_OPTS = ("sgd", "fedavgm", "fedadam", "fedyogi")
# adaptive families take a smaller server step than the identity replace
OPT_LR = {"sgd": 1.0, "fedavgm": 0.9, "fedadam": 0.1, "fedyogi": 0.1}
RATIOS = (0.0, 0.3, 0.5)
RESULTS = Path(__file__).resolve().parent.parent / "results"
OUT = RESULTS / "BENCH_server_opt.json"

N_CLIENTS = 18
N_ROUNDS = 8
COHORT = 6


def _setup(seed: int = 0):
    full = make_image_classification(1000, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:850], full.y[:850])
    test = ArrayDataset(full.x[850:], full.y[850:])
    parts = label_sorted_shards(train, N_CLIENTS, 2, seed=seed)
    test_parts = label_sorted_shards(test, N_CLIENTS, 2, seed=seed)
    task = ClassificationTask(
        make_cnn(14, 1, 5, 32, "bench_srvopt_cnn"),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


def run_bench(rounds: int = N_ROUNDS, seed: int = 0) -> dict:
    task, parts, test_parts = _setup(seed)
    grid: dict = {}
    for server_opt in SERVER_OPTS:
        for ratio in RATIOS:
            cfg = ExperimentConfig(
                strategy="fedlesscan", n_rounds=rounds,
                clients_per_round=COHORT, eval_every=0, seed=seed,
                server_opt=server_opt,
                server_opt_lr=OPT_LR[server_opt],
                scenario=ScenarioConfig(straggler_fraction=ratio,
                                        round_timeout_s=30.0, seed=seed))
            t0 = time.perf_counter()
            res = run_experiment(task, parts, test_parts, cfg)
            wall_s = time.perf_counter() - t0
            key = f"{server_opt}@{ratio}"
            grid[key] = {
                "server_opt": server_opt, "ratio": ratio,
                "server_opt_lr": OPT_LR[server_opt],
                "accuracy": res.final_accuracy,
                "eur": res.mean_eur,
                "duration_s": res.total_duration_s,
                "cost_usd": res.total_cost,
                "wall_s": round(wall_s, 3),
            }
            print(f"{key:18s} acc={res.final_accuracy:.3f} "
                  f"eur={res.mean_eur:.2f} "
                  f"dur={res.total_duration_s:7.1f}s "
                  f"cost=${res.total_cost:.4f}")
    return grid


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=N_ROUNDS)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    grid = run_bench(rounds=args.rounds, seed=args.seed)
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(grid, indent=1))
    print(f"\nwrote {OUT} ({len(grid)} cells)")


if __name__ == "__main__":
    main()
