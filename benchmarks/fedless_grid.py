"""Shared experiment grid: strategies × straggler ratios (paper §VI).

Tables II (accuracy/EUR), III (time) and IV (cost) all read from one grid
of simulated-FaaS FL runs, exactly like the paper derives its tables from
one set of experiments.  Results are cached to results/bench_grid.json.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn

STRATEGIES = ("fedavg", "fedprox", "fedlesscan", "safa")
RATIOS = (0.0, 0.1, 0.3, 0.5, 0.7)
RESULTS = Path(__file__).resolve().parent.parent / "results"
CACHE = RESULTS / "bench_grid.json"
# sync vs semi-async vs barrier-free, one straggler ratio (§ async study)
ASYNC_STRATEGIES = ("fedavg", "fedlesscan", "fedasync", "fedbuff")
ASYNC_RATIO = 0.3
ASYNC_CACHE = RESULTS / "async_grid.json"

N_CLIENTS = 24
N_ROUNDS = 10
CLIENTS_PER_ROUND = 6


def _setup(seed: int = 0):
    full = make_image_classification(2400, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:2000], full.y[:2000])
    test = ArrayDataset(full.x[2000:], full.y[2000:])
    parts = label_sorted_shards(train, N_CLIENTS, 2, seed=seed)
    test_parts = label_sorted_shards(test, N_CLIENTS, 2, seed=seed)
    model = make_cnn(14, 1, 5, 64, "bench_cnn")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


def run_grid(force: bool = False) -> dict:
    if CACHE.exists() and not force:
        return json.loads(CACHE.read_text())
    task, parts, test_parts = _setup()
    grid: dict = {}
    for strategy in STRATEGIES:
        for ratio in RATIOS:
            cfg = ExperimentConfig(
                strategy=strategy, n_rounds=N_ROUNDS,
                clients_per_round=CLIENTS_PER_ROUND, eval_every=0, seed=0,
                scenario=ScenarioConfig(straggler_fraction=ratio,
                                        round_timeout_s=30.0, seed=0))
            res = run_experiment(task, parts, test_parts, cfg)
            key = f"{strategy}@{ratio}"
            counts = res.invocation_counts()
            grid[key] = {
                "strategy": strategy, "ratio": ratio,
                "accuracy": res.final_accuracy,
                "eur": res.mean_eur,
                "duration_s": res.total_duration_s,
                "cost_usd": res.total_cost,
                "bias": res.bias,
                "invocations": sorted(counts.values()),
                "round_durations": [r.duration_s for r in res.rounds],
                # cost attribution (CostMeter breakdown)
                "cost_by_client": {cid: round(c, 9) for cid, c
                                   in sorted(res.cost_by_client.items())},
                "cost_by_round": [round(res.cost_by_round.get(i, 0.0), 9)
                                  for i in range(N_ROUNDS)],
            }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(grid, indent=1))
    return grid


def run_async_grid(force: bool = False) -> dict:
    """Training-mode comparison at one straggler ratio: FedAvg (sync),
    FedLesScan (semi-async), FedAsync/FedBuff (barrier-free), all on the
    same seed, task and straggler profile, with JSONL traces exported to
    results/traces/."""
    if ASYNC_CACHE.exists() and not force:
        return json.loads(ASYNC_CACHE.read_text())
    task, parts, test_parts = _setup()
    grid: dict = {}
    for strategy in ASYNC_STRATEGIES:
        trace = RESULTS / "traces" / f"{strategy}@{ASYNC_RATIO}.jsonl"
        cfg = ExperimentConfig(
            strategy=strategy, n_rounds=N_ROUNDS,
            clients_per_round=CLIENTS_PER_ROUND, eval_every=0, seed=0,
            trace_path=str(trace),
            scenario=ScenarioConfig(straggler_fraction=ASYNC_RATIO,
                                    round_timeout_s=30.0, seed=0))
        res = run_experiment(task, parts, test_parts, cfg)
        grid[strategy] = {
            "strategy": strategy, "mode": res.mode, "ratio": ASYNC_RATIO,
            "accuracy": res.final_accuracy,
            "eur": res.mean_eur,
            "duration_s": res.total_duration_s,
            "cost_usd": res.total_cost,
            # trailing non-aggregated accounting windows don't count
            "aggregations": sum(1 for r in res.rounds
                                if r.aggregated_updates > 0),
            "updates_delivered": sum(len(r.successes) for r in res.rounds),
            "trace": str(trace),
        }
    ASYNC_CACHE.parent.mkdir(parents=True, exist_ok=True)
    ASYNC_CACHE.write_text(json.dumps(grid, indent=1))
    return grid
