"""Update-compression benchmark — 4 schemes × 2 model scales.

Every scheme cell measures the three costs the compression stage trades
against each other:

* **bytes/round** — encoded wire size of one cohort's updates (for the
  small CNN, read back from the experiment's egress records; dense is
  the analytic ``P × 4`` fp32 payload);
* **encode/decode wall-time** — kernel-level micro-bench of the Pallas
  encode/decode pair on a flat parameter-sized vector;
* **merge wall-time vs device count** — one ``fed_agg_apply`` server
  update timed single-device and under the mesh-sharded ``shard_map``
  path (subprocess workers with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``, so each mesh
  size sees a fresh jax runtime).

The small-CNN cells additionally run the full FedLesScan experiment per
scheme (same seed/task/straggler profile, only the compressor varies) so
the JSON records the accuracy/cost impact next to the byte savings.

The gemma3-1b cells time encode/decode shard-wise (a real compressor
operates per-tensor) over ``--gemma-shards`` measured shards and scale
to the architecture's analytic ``param_count``; the JSON records both
the measured and the extrapolated figures.  Gemma cells are tier-2: run
with ``--model gemma`` (CI runs ``--model small`` only).

Results land in ``results/BENCH_compression.json``.

Run: ``PYTHONPATH=src python -m benchmarks.bench_compression``
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results"
OUT = RESULTS / "BENCH_compression.json"

# (name, scheme, topk_ratio)
SCHEMES = (
    ("dense", "none", 0.0),
    ("topk@1%", "topk", 0.01),
    ("topk@0.1%", "topk", 0.001),
    ("int8", "int8", 0.0),
)

N_CLIENTS = 18
N_ROUNDS = 6
COHORT = 6
CHUNK = 256
MESH_SIZES = (1, 2)
# sharded-merge slab cap: interpret-mode Pallas over the full 1B gemma
# vector is pointless on CPU; the per-element merge cost is flat in P
GEMMA_MERGE_P = 1 << 22
GEMMA_SHARD = 1 << 22


def _time_best(fn, iters: int = 3) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ----------------------------------------------------------------------
# kernel-level encode/decode micro-bench on one flat P-vector
# ----------------------------------------------------------------------
def _bench_codec(x: np.ndarray, scheme: str, topk_ratio: float) -> dict:
    import jax
    from repro.kernels import ops

    P = int(x.size)
    xs = jax.numpy.asarray(x)
    if scheme == "none":
        return {"payload_bytes": P * 4, "encode_s": 0.0, "decode_s": 0.0}
    if scheme == "topk":
        k = max(1, min(P, int(round(P * topk_ratio))))

        def enc():
            idx, vals, _ = ops.topk_encode(xs, k)
            jax.block_until_ready(vals)
            return idx, vals

        idx, vals = enc()
        dec = lambda: jax.block_until_ready(ops.topk_decode(idx, vals, P))
        return {"payload_bytes": k * 8, "encode_s": _time_best(enc),
                "decode_s": _time_best(dec)}
    # int8
    n_chunks = -(-P // CHUNK)

    def enc():
        q, scale = ops.int8_encode(xs, chunk=CHUNK)
        jax.block_until_ready(q)
        return q, scale

    q, scale = enc()
    dec = lambda: jax.block_until_ready(ops.int8_decode(q, scale, P))
    return {"payload_bytes": P + n_chunks * 4, "encode_s": _time_best(enc),
            "decode_s": _time_best(dec)}


# ----------------------------------------------------------------------
# merge wall-time vs mesh size (subprocess per device count: the host
# device count is fixed at first jax init, so each N needs its own
# process with XLA_FLAGS set before import)
# ----------------------------------------------------------------------
def _merge_worker(k: int, p: int) -> None:
    import jax
    from repro.kernels import ops
    from repro.launch.mesh import make_host_mesh

    devices = len(jax.devices())
    rng = np.random.default_rng(0)
    upd = jax.numpy.asarray(rng.normal(size=(k, p)).astype(np.float32))
    coeffs = jax.numpy.asarray(np.full(k, 1.0 / k, dtype=np.float32))
    params = jax.numpy.asarray(rng.normal(size=p).astype(np.float32))
    m = jax.numpy.zeros(p, np.float32)
    v = jax.numpy.zeros(p, np.float32)

    if devices > 1:
        mesh = make_host_mesh(data=devices)
        run = lambda: ops.fed_agg_apply_sharded(
            upd, coeffs, params, m, v, 0.1, 1.0, 0.9, 0.99, 1e-3,
            opt="fedadam", mesh=mesh)
    else:
        run = lambda: ops.fed_agg_apply(
            upd, coeffs, params, m, v, 0.1, 1.0, 0.9, 0.99, 1e-3,
            opt="fedadam")

    jax.block_until_ready(run())          # compile outside the timing
    wall = _time_best(lambda: jax.block_until_ready(run()))
    print(json.dumps({"devices": devices, "wall_s": wall}))


def _bench_merge(k: int, p: int) -> dict:
    out = {}
    for n in MESH_SIZES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_compression",
             "--merge-worker", str(k), str(p)],
            capture_output=True, text=True, env=env, check=True)
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[str(n)] = rec["wall_s"]
        print(f"  merge K={k} P={p} devices={n}: {rec['wall_s']:.4f}s")
    return out


# ----------------------------------------------------------------------
# small-CNN cells: full experiment per scheme + codec micro-bench
# ----------------------------------------------------------------------
def _small_cells(rounds: int, seed: int, tmpdir: Path) -> dict:
    import jax
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(1000, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:850], full.y[:850])
    test = ArrayDataset(full.x[850:], full.y[850:])
    parts = label_sorted_shards(train, N_CLIENTS, 2, seed=seed)
    test_parts = label_sorted_shards(test, N_CLIENTS, 2, seed=seed)
    task = ClassificationTask(
        make_cnn(14, 1, 5, 32, "bench_compress_cnn"),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    params = task.init_params(seed)
    flat = np.concatenate([np.ravel(np.asarray(l))
                           for l in jax.tree_util.tree_leaves(params)])
    P = int(flat.size)

    cells = {}
    for name, scheme, ratio in SCHEMES:
        trace = tmpdir / f"small_{scheme}_{ratio}.jsonl"
        cfg = ExperimentConfig(
            strategy="fedlesscan", n_rounds=rounds,
            clients_per_round=COHORT, eval_every=0, seed=seed,
            compress_scheme=scheme, compress_topk_ratio=ratio,
            compress_chunk=CHUNK, trace_path=str(trace),
            scenario=ScenarioConfig(straggler_fraction=0.3,
                                    round_timeout_s=30.0, seed=seed))
        t0 = time.perf_counter()
        res = run_experiment(task, parts, test_parts, cfg)
        wall_s = time.perf_counter() - t0
        recs = [json.loads(line) for line in trace.open()]
        payload = [r["payload_bytes"] for r in recs
                   if r["type"] == "aggregation" and "payload_bytes" in r]
        bytes_per_round = (float(np.mean(payload)) if payload
                           else COHORT * P * 4.0)
        codec = _bench_codec(flat.astype(np.float32), scheme, ratio)
        cells[name] = {
            "scheme": scheme, "topk_ratio": ratio, "param_count": P,
            "bytes_per_round": bytes_per_round,
            "dense_bytes_per_round": COHORT * P * 4.0,
            "compression_ratio": round(COHORT * P * 4.0 / bytes_per_round,
                                       3),
            "encode_s": round(codec["encode_s"], 5),
            "decode_s": round(codec["decode_s"], 5),
            "accuracy": res.final_accuracy,
            "cost_usd": res.total_cost,
            "eur": res.mean_eur,
            "wall_s": round(wall_s, 3),
        }
        print(f"small/{name:10s} bytes/round={bytes_per_round:12.0f} "
              f"ratio={cells[name]['compression_ratio']:7.1f}x "
              f"acc={res.final_accuracy:.3f}")
    return {"cells": cells,
            "merge_wall_s": _bench_merge(COHORT, P)}


# ----------------------------------------------------------------------
# gemma3-1b cells: shard-wise codec timing scaled to the full model
# ----------------------------------------------------------------------
def _gemma_cells(seed: int, shards: int) -> dict:
    from repro.configs.registry import get_config
    from repro.models.config import param_count

    P_total = int(param_count(get_config("gemma3-1b")))
    n_shards_total = -(-P_total // GEMMA_SHARD)
    shards = min(shards, n_shards_total)
    rng = np.random.default_rng(seed)

    cells = {}
    for name, scheme, ratio in SCHEMES:
        enc_s = dec_s = 0.0
        payload = 0
        for _ in range(shards):
            x = rng.normal(size=GEMMA_SHARD).astype(np.float32)
            codec = _bench_codec(x, scheme, ratio)
            enc_s += codec["encode_s"]
            dec_s += codec["decode_s"]
            payload += codec["payload_bytes"]
        scale = n_shards_total / shards
        cells[name] = {
            "scheme": scheme, "topk_ratio": ratio,
            "param_count": P_total,
            "measured_shards": shards, "total_shards": n_shards_total,
            "bytes_per_round": payload * scale * COHORT,
            "dense_bytes_per_round": float(P_total) * 4.0 * COHORT,
            "compression_ratio": round(
                P_total * 4.0 / (payload * scale), 3),
            "encode_s_extrapolated": round(enc_s * scale, 3),
            "decode_s_extrapolated": round(dec_s * scale, 3),
        }
        print(f"gemma/{name:10s} ratio="
              f"{cells[name]['compression_ratio']:7.1f}x "
              f"encode~{cells[name]['encode_s_extrapolated']:.1f}s")
    print(f"  (gemma merge slab capped at P={GEMMA_MERGE_P}; "
          f"codec measured on {shards}/{n_shards_total} shards)")
    return {"cells": cells, "merge_p": GEMMA_MERGE_P,
            "merge_wall_s": _bench_merge(COHORT, GEMMA_MERGE_P)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=N_ROUNDS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model", choices=("small", "gemma", "both"),
                    default="small")
    ap.add_argument("--gemma-shards", type=int, default=4)
    ap.add_argument("--merge-worker", nargs=2, type=int, metavar=("K", "P"),
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.merge_worker:
        _merge_worker(*args.merge_worker)
        return

    import tempfile
    grid: dict = {"mesh_sizes": list(MESH_SIZES)}
    if args.model in ("small", "both"):
        with tempfile.TemporaryDirectory() as d:
            grid["small_cnn"] = _small_cells(args.rounds, args.seed, Path(d))
    if args.model in ("gemma", "both"):
        grid["gemma3-1b"] = _gemma_cells(args.seed, args.gemma_shards)

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(grid, indent=1))
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
