"""Cohort-sharded executor scaling — K × model scale × device count.

Each cell times one bucketed cohort training dispatch
(`VectorizedExecutor.run_group_batch` + block) for cohort size
K ∈ {16, 64, 256} under a forced host device count N ∈ {1, 2, 8}
(subprocess workers with ``XLA_FLAGS=--xla_force_host_platform_
device_count=N``, since the device count is fixed at first jax init).
N = 1 is the plain vmap path; N > 1 splits the cohort dim over the
``("clients",)`` mesh via ``shard_map``.

Two model scales:

* **small CNN** — the paper's LEAF-style CNN at fc=16, one local epoch
  over 20 samples per client (CI runs this half);
* **gemma-scale shard** — a single 2048x2048 dense slab (~4.2M params,
  one sharded-gemma tensor shard), so the cohort stack at K=256 is a
  ~4.3 GB resident and the dispatch is memory-bandwidth-bound like a
  real large-model cohort.  Tier-2: run with ``--model gemma``/``both``.

Honesty caveat: forced host devices are *threads over the same
physical cores*.  On hosts where ``os.cpu_count()`` is less than the
forced device count (CI runners here have 1 core) the sharded cells
measure partitioning overhead, not parallel speedup — expect
``speedup_vs_1dev`` <= 1 there.  The JSON records ``host_cpu_count``
next to every ratio so readers can tell which regime produced it;
real >1 speedups need >= N cores or real accelerator devices.

Results land in ``results/BENCH_executor_scale.json``.

Run: ``PYTHONPATH=src python -m benchmarks.bench_executor_scale``
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results"
OUT = RESULTS / "BENCH_executor_scale.json"

K_SWEEP = (16, 64, 256)
DEVICE_COUNTS = (1, 2, 8)
SAMPLES_PER_CLIENT = 20
GEMMA_SHARD_DIM = 2048          # 2048x2048 dense slab ~= 4.2M params


def _time_best(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ----------------------------------------------------------------------
# worker: one (model, K) cell under this process's forced device count
# ----------------------------------------------------------------------
def _make_small_task():
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    model = make_cnn(14, 1, 5, 16, "bench_exec_cnn")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=10, per_sample_time_s=0.05))
    return task, (14, 14, 1), 5


def _make_gemma_shard_task():
    """One gemma-scale tensor shard as a trainable 'model': a single
    dense slab classified over its output dim, so the executor moves a
    real large-model parameter volume per client."""
    import jax
    import jax.numpy as jnp

    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import ModelDef, _dense, _dense_init

    d = GEMMA_SHARD_DIM

    def init(rng):
        return {"shard": _dense_init(rng, d, d)}

    def apply(params, x):
        return _dense(params["shard"], x)

    model = ModelDef(init, apply, "bench_gemma_shard")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=2, per_sample_time_s=0.05))
    del jax, jnp
    return task, (d,), d


def _cell_worker(model: str, k: int, reps: int) -> None:
    import jax

    from repro.data.synthetic import ArrayDataset
    from repro.fl.executor import VectorizedExecutor
    from repro.launch.mesh import make_clients_mesh

    devices = len(jax.devices())
    if model == "small":
        task, sample_shape, n_classes = _make_small_task()
        n = SAMPLES_PER_CLIENT
    else:
        task, sample_shape, n_classes = _make_gemma_shard_task()
        n = 4                                # 2 steps of batch 2
    rng = np.random.default_rng(0)
    datasets = [ArrayDataset(
        rng.normal(size=(n, *sample_shape)).astype(np.float32),
        rng.integers(0, n_classes, size=n).astype(np.int32))
        for _ in range(k)]
    cids = [f"c{i}" for i in range(k)]
    seeds = list(range(k))
    params = task.init_params(0)

    mesh = make_clients_mesh(devices) if devices > 1 else None
    ex = VectorizedExecutor(task, mesh=mesh)

    def dispatch():
        batch = ex.run_group_batch(cids, datasets, params, 0.0, seeds)
        jax.block_until_ready((batch.mat, batch._losses))

    dispatch()                               # compile outside the timing
    wall = _time_best(dispatch, reps)
    print(json.dumps({"model": model, "k": k, "devices": devices,
                      "wall_s": wall, "compiles": ex.compile_count}))


# ----------------------------------------------------------------------
# parent: subprocess per device count (XLA pins it at first import)
# ----------------------------------------------------------------------
def _run_cell(model: str, k: int, devices: int, reps: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_executor_scale",
         "--cell-worker", model, str(k), str(reps)],
        capture_output=True, text=True, env=env, check=True)
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _sweep(model: str, reps: int) -> dict:
    cells: dict = {}
    for k in K_SWEEP:
        per_dev = {}
        for n in DEVICE_COUNTS:
            rec = _run_cell(model, k, n, reps)
            per_dev[str(n)] = round(rec["wall_s"], 4)
            print(f"{model:6s} K={k:3d} devices={n}: "
                  f"{rec['wall_s']:.4f}s")
        base = per_dev["1"]
        cells[f"K={k}"] = {
            "wall_s": per_dev,
            "speedup_vs_1dev": {n: round(base / s, 3)
                                for n, s in per_dev.items() if n != "1"},
        }
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("small", "gemma", "both"),
                    default="small")
    ap.add_argument("--reps", type=int, default=2,
                    help="timed repetitions per cell (best-of)")
    ap.add_argument("--cell-worker", nargs=3,
                    metavar=("MODEL", "K", "REPS"), help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.cell_worker:
        _cell_worker(args.cell_worker[0], int(args.cell_worker[1]),
                     int(args.cell_worker[2]))
        return

    grid: dict = {
        "host_cpu_count": os.cpu_count(),
        "device_counts": list(DEVICE_COUNTS),
        "k_sweep": list(K_SWEEP),
        "note": ("forced host devices are threads over the same physical "
                 "cores; with host_cpu_count < devices the multi-device "
                 "cells measure shard_map partitioning overhead, not "
                 "parallel speedup — real speedups need >= N cores or "
                 "accelerator devices"),
        "models": {},
    }
    if args.model in ("small", "both"):
        grid["models"]["small_cnn"] = {
            "samples_per_client": SAMPLES_PER_CLIENT,
            "cells": _sweep("small", args.reps),
        }
    if args.model in ("gemma", "both"):
        grid["models"]["gemma_shard"] = {
            "shard_dim": GEMMA_SHARD_DIM,
            "param_count": GEMMA_SHARD_DIM * GEMMA_SHARD_DIM
            + GEMMA_SHARD_DIM,
            "cells": _sweep("gemma", max(1, args.reps - 1)),
        }

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(grid, indent=1))
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
