"""Fleet-scale hot-path benchmark: propose latency + events/sec at
10³ → 10⁶ registered clients.

Three measurements, written to ``results/BENCH_fleet_scale.json``:

1. **Propose latency** per scheduling policy (random / fedlesscan /
   apodotiko / rotation) over a synthetic behavioural population
   (70% participants, 10% stragglers, 20% rookies — so the fedlesscan
   path exercises tier masks, the dense EMA feature gather, and sketch
   clustering, not just the rookie fast path).  Reported as p50/p95 ms.

2. **Event-queue throughput**: schedule/pop (with a cancellation mix
   that exercises tombstone compaction) on the slotted `Event` heap,
   in events per second.

3. **Dict-baseline comparison** at ``--baseline-size``: the same
   scheduler-loop workload (propose a cohort, then feed every
   completion back as mark_success + client_report) run against the array-backed
   `ClientHistoryDB` and against a faithful reimplementation of the
   pre-refactor dict-of-`ClientRecord` store, whose per-propose tier
   partition walks every record in Python.  Reported as completions/sec
   each plus the speedup ratio — the ≥10× acceptance gate.

Run:  PYTHONPATH=src python -m benchmarks.bench_fleet_scale
CI:   PYTHONPATH=src python -m benchmarks.bench_fleet_scale \
          --sizes 1000 10000 --baseline-size 10000
Full: PYTHONPATH=src python -m benchmarks.bench_fleet_scale \
          --sizes 1000 10000 100000 1000000
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core.history import DEFAULT_EMA_ALPHA, ClientHistoryDB
from repro.faas.events import EventKind, EventQueue
from repro.fl.scheduler import (ApodotikoScheduler, FedLesScanScheduler,
                                RandomScheduler, RotationScheduler)

RESULTS = Path(__file__).resolve().parent.parent / "results"
OUT = RESULTS / "BENCH_fleet_scale.json"

DEFAULT_SIZES = (1_000, 10_000, 100_000, 1_000_000)
POLICIES = ("random", "fedlesscan", "apodotiko", "rotation")


def make_ids(n: int) -> List[str]:
    return [f"c{i:07d}" for i in range(n)]


def seed_history(n: int, seed: int = 0) -> tuple:
    """(db, ids): an array-backed store with a synthetic behavioural mix
    — ~90% participants (training history), 10% stragglers (cooldown +
    one miss), and at most 64 rookies, so a 256-cohort propose falls
    through the rookie fast path into tier masking, the dense EMA
    feature gather, and (sketch) clustering — the paths whose cost
    actually scales with fleet size.  Seeded straight into the
    struct-of-arrays (the per-event mutators are exercised by the
    baseline comparison; here we need a large populated fleet quickly).
    The ragged mirrors stay empty: features read the maintained dense
    columns."""
    ids = make_ids(n)
    db = ClientHistoryDB()
    db.ensure(ids)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_strag = int(n * 0.1)
    n_rookie = min(64, max(n - n_strag - 1, 0))
    n_part = n - n_strag - n_rookie
    part = perm[:n_part]
    strag = perm[n_part:n_part + n_strag]
    active = np.concatenate((part, strag))

    times = rng.lognormal(mean=1.0, sigma=0.5, size=active.size)
    invoc = rng.integers(1, 6, size=active.size)
    db._n_times[active] = invoc
    db._t_ema[active] = times
    db._t_max[active] = times * rng.uniform(1.0, 1.5, size=active.size)
    db._invocations[active] = invoc
    db._successes[active] = invoc
    db._last_round[active] = rng.integers(0, 10, size=active.size)

    db._cooldown[strag] = 2 ** rng.integers(0, 3, size=strag.size)
    db._failures[strag] = 1
    if db._missed_mat.shape[1] < 1:
        pad = np.full((db._missed_mat.shape[0], 4), np.inf, np.float64)
        db._missed_mat = pad
    db._missed_mat[strag, 0] = rng.integers(0, 8, size=strag.size)
    db._n_missed[strag] = 1
    db.rebuild_tiers()                  # direct array seeding bypassed
    return db, ids                      # the per-mutation tier syncs


def make_scheduler(policy: str, db: ClientHistoryDB, ids: List[str],
                   cohort: int, seed: int = 1):
    if policy == "random":
        return RandomScheduler(cohort, seed=seed)
    if policy == "fedlesscan":
        return FedLesScanScheduler(cohort, db, max_rounds=50, seed=seed)
    if policy == "apodotiko":
        sched = ApodotikoScheduler(cohort, seed=seed)
        # mirror the history mix into the scheduler's own tallies
        sched._interner.intern_many(ids)
        sched._capacity()
        n = len(ids)
        sched._dur[:n] = db._t_ema[:n]
        sched._seen[:n] = db._n_times[:n] > 0
        sched._obs[:n] = db._invocations[:n] + db._failures[:n]
        sched._succ[:n] = db._successes[:n]
        sched._fin[:n] = db._successes[:n]
        return sched
    if policy == "rotation":
        return RotationScheduler(cohort, ids, timeout_s=120.0, seed=seed)
    raise ValueError(policy)


def bench_propose(n: int, cohort: int, reps: int, seed: int = 0
                  ) -> Dict[str, dict]:
    db, ids = seed_history(n, seed)
    out: Dict[str, dict] = {}
    for policy in POLICIES:
        sched = make_scheduler(policy, db, ids, cohort)
        sched.propose(ids, cohort, 0.0, 0)       # warm-up (interner memo)
        lat = []
        for r in range(reps):
            t0 = time.perf_counter()
            picks = sched.propose(ids, cohort, float(r + 1), r + 1)
            lat.append((time.perf_counter() - t0) * 1000.0)
            assert len(picks) > 0
        out[policy] = {
            "p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p95_ms": round(float(np.percentile(lat, 95)), 3),
            "max_ms": round(max(lat), 3),
        }
        print(f"  propose {policy:11s} n={n:>9,}  "
              f"p50={out[policy]['p50_ms']:8.2f}ms  "
              f"p95={out[policy]['p95_ms']:8.2f}ms")
    return out


def bench_event_queue(n_events: int, seed: int = 0) -> dict:
    """Schedule/pop throughput with a 25% cancellation mix (tombstone
    compaction included in the measured time)."""
    rng = np.random.default_rng(seed)
    q = EventQueue(trace_maxlen=1024)
    times = rng.uniform(0.0, 1e6, size=n_events)
    cancel_mask = rng.random(n_events) < 0.25
    t0 = time.perf_counter()
    events = [q.schedule(float(times[i]), EventKind.CLIENT_FINISH,
                         client_id="c", round_number=0)
              for i in range(n_events)]
    for i in np.flatnonzero(cancel_mask):
        events[i].cancel()
    popped = 0
    while q.pop() is not None:
        popped += 1
    elapsed = time.perf_counter() - t0
    assert popped == n_events - int(cancel_mask.sum())
    return {"n_events": n_events, "popped": popped,
            "events_per_sec": round(n_events / elapsed),
            "elapsed_s": round(elapsed, 3)}


# ---------------------------------------------------------------------------
# Dict-backed baseline: the pre-refactor store shape.  One dataclass-like
# record per client in a dict; every propose partitions the whole pool by
# walking the records in Python (exactly what `ClientHistoryDB.partition`
# + per-record tier properties did before the array store).
# ---------------------------------------------------------------------------

class _DictRecord:
    __slots__ = ("training_times", "missed_rounds", "cooldown",
                 "invocations", "successes", "failures", "last_round")

    def __init__(self):
        self.training_times: List[float] = []
        self.missed_rounds: List[int] = []
        self.cooldown = 0
        self.invocations = 0
        self.successes = 0
        self.failures = 0
        self.last_round = -1

    @property
    def is_rookie(self):
        return not self.training_times and not self.missed_rounds


class _DictHistoryDB:
    def __init__(self, ids: List[str]):
        self.records = {cid: _DictRecord() for cid in ids}

    def partition(self, ids):
        rookies, participants, stragglers = [], [], []
        for cid in ids:
            rec = self.records[cid]
            if rec.is_rookie:
                rookies.append(cid)
            elif rec.cooldown > 0:
                stragglers.append(cid)
            else:
                participants.append(cid)
        return rookies, participants, stragglers

    def mark_success(self, cid, rnd):
        rec = self.records[cid]
        rec.cooldown = 0
        rec.successes += 1
        rec.invocations += 1
        rec.last_round = rnd

    def client_report(self, cid, rnd, t):
        rec = self.records[cid]
        rec.training_times.append(float(t))
        if rnd in rec.missed_rounds:
            rec.missed_rounds.remove(rnd)


def _loop_dict(ids: List[str], iters: int, refill: int, seed: int) -> int:
    db = _DictHistoryDB(ids)
    rng = np.random.default_rng(seed)
    done = 0
    for r in range(iters):
        rookies, participants, stragglers = db.partition(ids)
        pool = rookies if len(rookies) >= refill else ids
        pos = rng.choice(len(pool), size=min(refill, len(pool)),
                         replace=False)
        for p in pos:
            cid = pool[int(p)]
            db.mark_success(cid, r)
            db.client_report(cid, r, 2.5)
            done += 1
    return done


def _loop_array(ids: List[str], iters: int, refill: int, seed: int) -> int:
    db = ClientHistoryDB()
    db.ensure(ids)
    rng = np.random.default_rng(seed)
    done = 0
    for r in range(iters):
        idx = db.indices_for(ids)
        rookie_m, _, _ = db.tier_masks(idx)
        rookie_idx = idx[rookie_m]
        source = rookie_idx if rookie_idx.size >= refill else idx
        pos = rng.choice(source.size, size=min(refill, source.size),
                         replace=False)
        for cid in db.ids_of(source[pos]):
            db.mark_success(cid, r)
            db.client_report(cid, r, 2.5)
            done += 1
    return done


def bench_baseline_comparison(n: int, iters: int, refill: int,
                              seed: int = 0) -> dict:
    """Async-style scheduler loop on both stores: every slot refill is
    one propose (tier partition over the whole registered pool + pick)
    followed by the refilled clients' completion feedback — exactly the
    per-event pattern the barrier-free driver runs.  The dict baseline
    pays an O(N)-record Python partition per event; the array store pays
    a vectorized mask pass.  Reported in completions/sec."""
    ids = make_ids(n)
    t0 = time.perf_counter()
    done_a = _loop_array(ids, iters, refill, seed)
    t_array = time.perf_counter() - t0
    t0 = time.perf_counter()
    done_d = _loop_dict(ids, iters, refill, seed)
    t_dict = time.perf_counter() - t0
    assert done_a == done_d
    eps_a, eps_d = done_a / t_array, done_d / t_dict
    out = {
        "size": n, "iters": iters, "refill": refill,
        "completions": done_a,
        "array_events_per_sec": round(eps_a, 1),
        "dict_events_per_sec": round(eps_d, 1),
        "speedup": round(eps_a / eps_d, 2),
    }
    print(f"  baseline n={n:,}: array={eps_a:,.0f} ev/s  "
          f"dict={eps_d:,.0f} ev/s  speedup={out['speedup']}x")
    return out


def run_bench(sizes, cohort: int, reps: int, baseline_size: int,
              baseline_iters: int, seed: int = 0) -> dict:
    report: dict = {"sizes": list(sizes), "cohort": cohort,
                    "ema_alpha": DEFAULT_EMA_ALPHA,
                    "propose": {}, "event_queue": {}}
    for n in sizes:
        print(f"n = {n:,}")
        report["propose"][str(n)] = bench_propose(n, cohort, reps, seed)
        ev = bench_event_queue(min(4 * n, 400_000), seed)
        report["event_queue"][str(n)] = ev
        print(f"  event queue: {ev['events_per_sec']:,} ev/s "
              f"({ev['n_events']:,} events)")
    report["baseline_comparison"] = bench_baseline_comparison(
        baseline_size, baseline_iters, 8, seed)
    biggest = str(max(sizes))
    report["acceptance"] = {
        "max_size": int(biggest),
        "worst_propose_p50_ms": max(
            p["p50_ms"] for p in report["propose"][biggest].values()),
        "baseline_speedup": report["baseline_comparison"]["speedup"],
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=list(DEFAULT_SIZES))
    ap.add_argument("--cohort", type=int, default=256)
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--baseline-size", type=int, default=100_000)
    ap.add_argument("--baseline-iters", type=int, default=100,
                    help="slot-refill proposes in the baseline comparison")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=Path, default=OUT)
    args = ap.parse_args()

    report = run_bench(args.sizes, args.cohort, args.reps,
                       args.baseline_size, args.baseline_iters, args.seed)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(report, indent=2) + "\n")
    acc = report["acceptance"]
    print(f"wrote {args.out}")
    print(f"worst propose p50 at n={acc['max_size']:,}: "
          f"{acc['worst_propose_p50_ms']}ms | baseline speedup: "
          f"{acc['baseline_speedup']}x")


if __name__ == "__main__":
    main()
