"""Device-resident round pipeline benchmark — handoff + end-to-end.

Two measurements, both pipeline-on vs pipeline-off
(``REPRO_DEVICE_PIPELINE``):

* **handoff cells** — the executor→merge handoff in isolation, cohort
  K ∈ {16, 64, 256} × {small-CNN-sized pytree, gemma3-1b-scale flat
  shard}.  The legacy path materializes one pytree per client from the
  stacked training output, then ``flat_update_matrix`` re-ravels and
  re-stacks them inside the merge (2·K·P extra device copies per
  round); the pipeline path flattens the stack once into a
  ``DeviceUpdateBatch`` and the merge gathers rows straight out of it
  with the update matrix donated to the fused server-update kernel.
  Both paths end in the same ``MergePipeline.merge`` (fedadam) and are
  timed to ``block_until_ready``.

* **end-to-end cell** (small CNN only) — the full FedLesScan experiment
  with the vectorized driver, identical seed/task/stragglers, toggling
  only the env gate; records wall-clock per round and the host-transfer
  byte counters from ``core.device_batch.transfer_stats`` (dense path:
  pipeline materializes ~0 bytes vs the legacy 2·K·model-size churn).

The gemma-scale cells run on a ``GEMMA_P``-element shard (the per-
element handoff cost is flat in P, same slab convention as
``bench_compression``); they are tier-2: run with ``--model gemma``
(CI runs ``--model small`` only).

Results land in ``results/BENCH_round_pipeline.json``.

Run: ``PYTHONPATH=src python -m benchmarks.bench_round_pipeline``
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results"
OUT = RESULTS / "BENCH_round_pipeline.json"

COHORTS = (16, 64, 256)
GEMMA_P = 1 << 22          # 4M-element shard of the 1B-param model
E2E_ROUNDS = 4
E2E_COHORT = 6
N_CLIENTS = 18

# leaf shapes mimicking the small CNN's pytree structure (P ≈ 71k)
SMALL_LEAVES = {"conv1": (3, 3, 1, 32), "conv2": (3, 3, 32, 32),
                "dense": (1568, 32), "head": (32, 5)}


def _time_best(fn, iters: int = 3) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ----------------------------------------------------------------------
# handoff cells: stacked training output → (K, P) merge-ready matrix
# (→ merged params when include_merge)
# ----------------------------------------------------------------------
def _handoff_cell(k: int, leaves: dict, include_merge: bool) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.flatten_util import ravel_pytree

    from repro.core.aggregation import (ClientUpdate, fedavg_coefficients,
                                        flat_update_matrix)
    from repro.core.device_batch import DeviceUpdateBatch
    from repro.core.merge import MergePipeline, ServerOptConfig
    from repro.fl.executor import VectorizedExecutor

    rng = np.random.default_rng(0)
    stacked = {name: jnp.asarray(
        rng.normal(size=(k,) + shape).astype(np.float32))
        for name, shape in leaves.items()}
    gp = jax.tree_util.tree_map(lambda l: l[0] * 0.0, stacked)
    p_total = sum(int(np.prod(s)) for s in leaves.values())
    cids = [f"c{i}" for i in range(k)]
    flatten = jax.jit(VectorizedExecutor._flatten_stacked)
    _, unravel = ravel_pytree(gp)

    def finish(updates):
        if include_merge:
            merger = MergePipeline(ServerOptConfig(name="fedadam", lr=0.1))
            out = merger.merge(gp, updates, fedavg_coefficients(updates))
            jax.block_until_ready(jax.tree_util.tree_leaves(out))
        else:
            # handoff only: stop at the merge-ready matrix — on CPU the
            # interpret-mode merge kernel would drown the copy traffic
            # this cell isolates (2·K·P legacy churn vs flatten+gather)
            mat, _ = flat_update_matrix(updates)
            jax.block_until_ready(mat)

    def legacy_round():
        finish([
            ClientUpdate(cid,
                         jax.tree_util.tree_map(lambda l, i=i: l[i], stacked),
                         10, 0)
            for i, cid in enumerate(cids)])

    def pipeline_round():
        batch = DeviceUpdateBatch(flatten(stacked), cids, unravel)
        finish([ClientUpdate(cid, num_samples=10, round_number=0,
                             batch=batch, batch_row=i)
                for i, cid in enumerate(cids)])

    legacy_round(); pipeline_round()          # compile outside the timing
    # the gemma-scale legacy cells run minutes per call at K=256 — one
    # post-warmup measurement there, best-of-3 at small scale
    iters = 3 if include_merge else 1
    legacy_s = _time_best(legacy_round, iters)
    pipeline_s = _time_best(pipeline_round, iters)
    return {"cohort": k, "param_count": p_total,
            "includes_merge": include_merge,
            "legacy_s": round(legacy_s, 5),
            "pipeline_s": round(pipeline_s, 5),
            "speedup": round(legacy_s / pipeline_s, 3)}


def _handoff_grid(model: str) -> list:
    # small cells run handoff + fused merge end to end; the gemma-scale
    # cells time the handoff alone (see _handoff_cell)
    leaves = (SMALL_LEAVES if model == "small"
              else {"shard": (GEMMA_P,)})
    cells = []
    for k in COHORTS:
        cell = _handoff_cell(k, leaves, include_merge=(model == "small"))
        cells.append(cell)
        print(f"{model}/handoff K={k:4d} P={cell['param_count']:9d} "
              f"legacy={cell['legacy_s']:.4f}s "
              f"pipeline={cell['pipeline_s']:.4f}s "
              f"-> {cell['speedup']:.2f}x", flush=True)
    return cells


# ----------------------------------------------------------------------
# end-to-end small-CNN experiment, env gate toggled; each gate runs in
# its own subprocess so neither inherits the other's in-process JIT
# cache (compile costs would otherwise all land on whichever runs first)
# ----------------------------------------------------------------------
def _e2e_worker(rounds: int, seed: int) -> None:
    from repro.core.device_batch import (reset_transfer_stats,
                                         transfer_stats)
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(1000, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:850], full.y[:850])
    test = ArrayDataset(full.x[850:], full.y[850:])
    parts = label_sorted_shards(train, N_CLIENTS, 2, seed=seed)
    test_parts = label_sorted_shards(test, N_CLIENTS, 2, seed=seed)
    task = ClassificationTask(
        make_cnn(14, 1, 5, 32, "bench_pipeline_cnn"),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    import jax
    P = sum(int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(task.init_params(seed)))

    cfg = ExperimentConfig(
        strategy="fedlesscan", n_rounds=rounds,
        clients_per_round=E2E_COHORT, eval_every=0, seed=seed,
        vectorized=True, executor_warmup=True,
        scenario=ScenarioConfig(straggler_fraction=0.3,
                                round_timeout_s=30.0, seed=seed))
    run_experiment(task, parts, test_parts, cfg)   # warm every dispatch
    reset_transfer_stats()
    t0 = time.perf_counter()
    res = run_experiment(task, parts, test_parts, cfg)
    wall = time.perf_counter() - t0
    stats = transfer_stats()
    print(json.dumps({
        "param_count": P,
        "wall_s": round(wall, 3),
        "round_s": round(wall / rounds, 4),
        "materialize_bytes": stats["materialize_bytes"],
        "materialize_rows": stats["materialize_rows"],
        "loss_syncs": stats["loss_syncs"],
        "accuracy": res.final_accuracy,
    }))


def _e2e_cell(rounds: int, seed: int) -> dict:
    import subprocess
    import sys

    out = {"rounds": rounds, "cohort": E2E_COHORT}
    for label, gate in (("pipeline", "1"), ("legacy", "0")):
        env = dict(os.environ)
        env["REPRO_DEVICE_PIPELINE"] = gate
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_round_pipeline",
             "--e2e-worker", str(rounds), str(seed)],
            capture_output=True, text=True, env=env, check=True)
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[label] = rec
        print(f"e2e/{label:8s} wall={rec['wall_s']:.2f}s "
              f"materialized={rec['materialize_bytes']} bytes "
              f"loss_syncs={rec['loss_syncs']}")
    P = out["pipeline"]["param_count"]
    out["round_speedup"] = round(
        out["legacy"]["wall_s"] / out["pipeline"]["wall_s"], 3)
    # the dense-path transfer claim: pipeline materializes ≤ 1 model of
    # bytes per round vs the legacy 2·K·P·4 analytic churn
    out["model_bytes"] = P * 4
    out["legacy_transfer_bytes_analytic"] = 2 * E2E_COHORT * P * 4 * rounds
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=E2E_ROUNDS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model", choices=("small", "gemma", "both"),
                    default="small")
    ap.add_argument("--e2e-worker", nargs=2, type=int,
                    metavar=("ROUNDS", "SEED"), help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.e2e_worker:
        _e2e_worker(*args.e2e_worker)
        return

    grid: dict = {"cohorts": list(COHORTS)}
    if args.model in ("small", "both"):
        grid["small_cnn"] = {"handoff": _handoff_grid("small"),
                             "e2e": _e2e_cell(args.rounds, args.seed)}
    if args.model in ("gemma", "both"):
        grid["gemma3-1b_shard"] = {"shard_p": GEMMA_P,
                                   "handoff": _handoff_grid("gemma")}

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(grid, indent=1))
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
