"""Render the §Roofline / §Dry-run markdown tables from results/dryrun/.

  PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, variant: str = "baseline"):
    rows = []
    suffix = f"__{mesh}.json" if variant == "baseline" else \
        f"__{mesh}__{variant}.json"
    for f in sorted(RESULTS.glob(f"*{suffix}")):
        stem = f.name[:-len(suffix)]
        if variant == "baseline" and stem.count("__") != 1:
            continue
        rows.append(json.loads(f.read_text()))
    rows.sort(key=lambda d: (d["arch"], SHAPE_ORDER.index(d["shape"])))
    return rows


def fmt(x: float) -> str:
    return f"{x:.2e}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    print(f"### Roofline — {args.mesh}-pod mesh, variant={args.variant}\n")
    print("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | useful ratio | per-dev args (GB) |")
    print("|---|---|---|---|---|---|---|---|")
    for d in load(args.mesh, args.variant):
        if d.get("status") == "skipped":
            print(f"| {d['arch']} | {d['shape']} | — | — | — | "
                  f"skipped (full attention @500k) | — | — |")
            continue
        if d.get("status") != "ok":
            print(f"| {d['arch']} | {d['shape']} | — | — | — | "
                  f"{d.get('status')} | — | — |")
            continue
        r = d["roofline"]
        mem = d.get("memory_analysis", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        print(f"| {d['arch']} | {d['shape']} | {fmt(r['compute_s'])} | "
              f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
              f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
              f"{args_gb:.2f} |")


if __name__ == "__main__":
    main()
